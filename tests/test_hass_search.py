"""HASS end-to-end on a reduced ResNet-18 (the paper's Fig. 5 structure)."""
import jax
import numpy as np
import pytest

from repro.configs import reduce_config
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import ParetoFrontier, incremental_dse
from repro.core.hass import (CNNEvaluator, Lambdas, frontier_hw_metrics,
                             hass_search)
from repro.core.perf_model import FPGAModel
from repro.models import cnn

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def evaluator():
    cfg = reduce_config(RESNET18)
    params = cnn.init_params(cfg, RNG)
    images = jax.random.normal(RNG, (8, cfg.img_res, cfg.img_res, 3))
    return CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                        dse_iters=400)


def test_evaluator_metric_contract(evaluator):
    m = evaluator(np.full(2 * len(evaluator.prunable), 0.4))
    assert 0.0 <= m["acc"] <= 1.0
    assert 0.0 <= m["spa"] <= 1.0
    assert m["thr"] > 0 and m["dsp"] <= 1.0 + 1e-6


def test_dense_proposal_gives_perfect_acc(evaluator):
    m = evaluator(np.zeros(2 * len(evaluator.prunable)))
    assert m["acc"] == 1.0
    assert m["spa"] < 0.45          # only natural relu zeros


def test_sparsity_increases_modeled_throughput(evaluator):
    lo = evaluator(np.zeros(2 * len(evaluator.prunable)))
    hi = evaluator(np.full(2 * len(evaluator.prunable), 0.7))
    assert hi["thr"] > lo["thr"]


def test_evaluate_batch_matches_serial(evaluator):
    """One vmapped prune+forward for B proposals == B serial jit calls (up
    to vmap-vs-jit float reassociation)."""
    L = len(evaluator.prunable)
    xs = [np.zeros(2 * L), np.full(2 * L, 0.4), np.full(2 * L, 0.75)]
    batch = evaluator.evaluate_batch(xs)
    assert len(batch) == 3
    for x, mb in zip(xs, batch):
        ms = evaluator(x)
        for k in ms:
            assert mb[k] == pytest.approx(ms[k], rel=1e-3, abs=1e-6), k


def test_batched_search_on_cnn_evaluator(evaluator):
    r = hass_search(evaluator, len(evaluator.prunable), iters=6,
                    s_max=0.9, seed=0, batch_size=3)
    assert len(r.trials) == 6
    assert 0.0 <= r.best_metrics["acc"] <= 1.0
    assert r.best_metrics["thr"] > 0


def test_metrics_pick_eq6_optimal_frontier_point(evaluator):
    """``frontier_mode="point"``: the hardware terms are scored at the
    frontier point maximizing the Eq. 6 combination — one DSE run, no
    re-search over budgets."""
    L = len(evaluator.prunable)
    x = np.full(2 * L, 0.5)
    old_mode = evaluator.frontier_mode
    evaluator.frontier_mode = "point"
    try:
        m = evaluator(x)
    finally:
        evaluator.frontier_mode = old_mode
    layers = evaluator.sparse_layers(x)
    f = incremental_dse(layers, evaluator.hw, evaluator.budget,
                        max_iters=evaluator.dse_iters).frontier
    thr_pts = f.thr * evaluator.hw.freq
    thr_norm = np.log2(1.0 + thr_pts / evaluator.dense_thr) / 4.0
    dsp = f.res / evaluator.budget
    lam = evaluator.lambdas
    scores = lam.thr * thr_norm - lam.dsp * dsp
    k = int(np.argmax(scores))
    assert m["thr"] == pytest.approx(float(thr_pts[k]))
    assert m["dsp"] == pytest.approx(float(dsp[k]))
    # never worse than always paying the full-budget endpoint (last point)
    assert scores[k] >= scores[-1] - 1e-15


def test_metrics_budgets_mode_scalarizes_the_frontier(evaluator):
    """``frontier_mode="budgets"`` (default): thr_norm/dsp are the MEANS of
    the per-deployment-budget values read off the frontier at each
    ``budget_fracs`` point (DESIGN.md §12)."""
    L = len(evaluator.prunable)
    x = np.full(2 * L, 0.5)
    assert evaluator.frontier_mode == "budgets"
    m = evaluator(x)
    layers = evaluator.sparse_layers(x)
    f = incremental_dse(layers, evaluator.hw, evaluator.budget,
                        max_iters=evaluator.dse_iters).frontier
    thr_pts = f.thr * evaluator.hw.freq
    thr_norm = np.log2(1.0 + thr_pts / evaluator.dense_thr) / 4.0
    tn, dp = [], []
    for frac in evaluator.budget_fracs:
        k = f.best_under(frac * evaluator.budget)
        k = 0 if k is None else k
        tn.append(float(thr_norm[k]))
        dp.append(float(f.res[k]) / evaluator.budget)
    assert m["thr_norm"] == pytest.approx(float(np.mean(tn)))
    assert m["dsp"] == pytest.approx(float(np.mean(dp)))
    k_full = f.best_under(evaluator.budget)
    assert m["thr"] == pytest.approx(float(thr_pts[k_full]))


def test_ragged_tail_batch_is_padded_to_one_compiled_shape(evaluator):
    """Batch-shape bucketing: a search whose last round is ragged pads it to
    the fixed batch shape, so no new vmapped executable is compiled, and the
    padded rows never reach tell_batch."""
    shapes_before = set(evaluator.batch_shapes)
    padded_before = evaluator.padded_batches
    r = hass_search(evaluator, len(evaluator.prunable), iters=8,
                    s_max=0.9, seed=1, batch_size=3)    # rounds 3 + 3 + 2
    assert len(r.trials) == 8                           # padding masked out
    assert evaluator.padded_batches > padded_before
    assert evaluator.batch_shapes - shapes_before <= {3}
    # a padded-round trial scores the same as the serial evaluator
    t = r.trials[-1]
    ms = evaluator(t.x)
    for k in ms:
        assert t.metrics[k] == pytest.approx(ms[k], rel=1e-3, abs=1e-6), k


class _FakeEv:
    """Minimal evaluator facade for frontier_hw_metrics property tests."""

    def __init__(self, budget=100.0, mode="budgets",
                 fracs=(0.25, 0.5, 0.75, 1.0)):
        self.budget = budget
        self.frontier_mode = mode
        self.budget_fracs = fracs
        self.lambdas = Lambdas()
        self.dense_thr = 1.0
        self.hw = FPGAModel()

    def _hw_terms(self, res, thr):
        thr_s = thr * self.hw.freq
        thr_norm = np.log2(1.0 + thr_s / self.dense_thr) / 4.0
        return thr_s, thr_norm, res / self.budget

    def _eq6_hw_score(self, res, thr):
        _, thr_norm, dsp = self._hw_terms(res, thr)
        return self.lambdas.thr * thr_norm - self.lambdas.dsp * dsp


def _frontier(res, thr):
    res = np.asarray(res, float)
    thr = np.asarray(thr, float)
    L = 2
    k = len(res)
    return ParetoFrontier(res=res, thr=thr,
                          spe=np.ones((k, L), np.int64),
                          n=np.ones((k, L), np.int64))


def test_frontier_scalarization_monotone_in_throughput():
    """Raising throughput anywhere on the frontier (same resource profile)
    never lowers the budgets-mode Eq. 6 hardware score."""
    ev = _FakeEv()
    res = [10.0, 25.0, 60.0, 100.0]
    thr = np.array([1e-9, 2e-9, 3e-9, 4e-9])
    base = frontier_hw_metrics(ev, _frontier(res, thr))
    lam = ev.lambdas

    def hw_score(m):
        return lam.thr * m["thr_norm"] - lam.dsp * m["dsp"]

    for j in range(len(res)):
        up = thr.copy()
        up[j:] = up[j:] * 1.5          # keep the frontier sorted/increasing
        m = frontier_hw_metrics(ev, _frontier(res, up))
        assert m["thr_norm"] >= base["thr_norm"] - 1e-15
        assert m["dsp"] == base["dsp"]
        assert hw_score(m) >= hw_score(base) - 1e-15


def test_frontier_scalarization_is_mean_of_per_budget_scores():
    """Eq. 6 is linear in (thr_norm, dsp), so the budgets-mode hardware
    score equals the MEAN of the per-deployment-budget Eq. 6 scores."""
    ev = _FakeEv()
    f = _frontier([10.0, 25.0, 60.0, 100.0], [1e-9, 2e-9, 3e-9, 4e-9])
    m = frontier_hw_metrics(ev, f)
    lam = ev.lambdas
    per_budget = []
    for frac in ev.budget_fracs:
        k = f.best_under(frac * ev.budget)
        _, tn, dsp = ev._hw_terms(f.res[k], f.thr[k])
        per_budget.append(lam.thr * float(tn) - lam.dsp * float(dsp))
    combined = lam.thr * m["thr_norm"] - lam.dsp * m["dsp"]
    assert combined == pytest.approx(float(np.mean(per_budget)))


def test_frontier_point_mode_matches_select():
    ev = _FakeEv(mode="point")
    f = _frontier([10.0, 25.0, 60.0, 100.0], [1e-9, 2e-9, 3e-9, 4e-9])
    m = frontier_hw_metrics(ev, f)
    k = f.select(ev._eq6_hw_score)
    thr_s, tn, dsp = ev._hw_terms(f.res, f.thr)
    assert m["thr"] == float(thr_s[k]) and m["dsp"] == float(dsp[k])


def test_unknown_frontier_mode_raises():
    ev = _FakeEv(mode="hypervolume")
    with pytest.raises(ValueError):
        frontier_hw_metrics(ev, _frontier([10.0, 100.0], [1e-9, 4e-9]))


@pytest.mark.slow
def test_hw_aware_search_beats_software_only(evaluator):
    """Fig. 5: at equal iteration budget, the hardware-aware objective finds
    higher computation efficiency (throughput/resource)."""
    kw = dict(iters=12, s_max=0.9, seed=0)
    hw = hass_search(evaluator, len(evaluator.prunable),
                     hardware_aware=True, **kw)
    sw = hass_search(evaluator, len(evaluator.prunable),
                     hardware_aware=False, **kw)
    assert hw.best_metrics["eff"] >= sw.best_metrics["eff"]
    # both retain usable accuracy proxies
    assert hw.best_metrics["acc"] >= 0.5
    assert len(hw.trials) == 12
    # running_best is monotone in score
    rb = hw.running_best("score")
    assert all(b >= a - 1e-12 for a, b in zip(rb, rb[1:]))


# --------------------------------------------------------------------- #
# Sparsity-pattern axis regressions (DESIGN.md §16): the degenerate
# pattern axis must replay the pre-pattern code path bit for bit.
# --------------------------------------------------------------------- #
def _cnn_pair(patterns):
    cfg = reduce_config(RESNET18)
    params = cnn.init_params(cfg, RNG)
    images = jax.random.normal(RNG, (8, cfg.img_res, cfg.img_res, 3))
    base = CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                        dse_iters=150)
    pat = CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                       dse_iters=150, patterns=patterns)
    return base, pat


def test_cnn_unstructured_only_pattern_axis_is_bit_identical_serial():
    """patterns=("unstructured",) adds no TPE dims and routes through the
    seed pruner — the whole search transcript is trial-for-trial identical
    to patterns=None."""
    base, pat = _cnn_pair(("unstructured",))
    assert pat.n_pattern_dims == 0
    kw = dict(iters=5, s_max=0.9, seed=1)
    r0 = hass_search(base, len(base.prunable), **kw)
    r1 = hass_search(pat, len(pat.prunable), **kw)
    for t0, t1 in zip(r0.trials, r1.trials):
        assert np.array_equal(t0.x, t1.x)
        assert t0.metrics == t1.metrics
        assert t0.score == t1.score
    assert r0.best_score == r1.best_score


def test_cnn_unstructured_only_pattern_axis_is_bit_identical_batched():
    base, pat = _cnn_pair(("unstructured",))
    kw = dict(iters=6, s_max=0.9, seed=2, batch_size=3)
    r0 = hass_search(base, len(base.prunable), **kw)
    r1 = hass_search(pat, len(pat.prunable), **kw)
    for t0, t1 in zip(r0.trials, r1.trials):
        assert np.array_equal(t0.x, t1.x)
        assert t0.metrics == t1.metrics


def test_cnn_pattern_search_picks_patterns_and_emits_meas():
    """Full pattern axis: the TPE gets one categorical dim per prunable
    layer, trials carry per-layer pattern codes, and with pattern_costs the
    measured Eq. 6 term appears in every metrics dict."""
    from repro.core.perf_model import TPUModel
    cfg = reduce_config(RESNET18)
    params = cnn.init_params(cfg, RNG)
    images = jax.random.normal(RNG, (4, cfg.img_res, cfg.img_res, 3))
    tpu = TPUModel()
    costs = {"unstructured": 1.0, "nm": 2.2, "hierarchical": 1.8,
             "activation": 1.0}
    ev = CNNEvaluator(cfg, params, images, tpu, budget=tpu.chip_budget,
                      dse_iters=100, patterns=("unstructured", "nm",
                                               "hierarchical", "activation"),
                      pattern_costs=costs)
    L = len(ev.prunable)
    assert ev.n_pattern_dims == L
    r = hass_search(ev, L, iters=4, s_max=0.9, seed=0,
                    lambdas=Lambdas(meas=0.1))
    assert len(r.trials) == 4
    for t in r.trials:
        # s_w dims + s_a dims (include_act default) + pattern dims
        assert len(t.x) == 3 * L
        codes = t.x[-L:]
        assert np.all((codes >= 0) & (codes < 4))
        assert "meas" in t.metrics and t.metrics["meas"] >= 0.0
    # patterned layers are labeled on the LayerCost stack
    layers = ev.sparse_layers(r.best_x)
    names = {l.pattern for l in layers if l.prunable}
    assert names <= {"unstructured", "nm", "hierarchical", "activation"}


def test_cnn_pattern_evaluate_batch_matches_serial():
    base, ev = _cnn_pair(("unstructured", "nm", "hierarchical"))
    del base
    L = len(ev.prunable)
    rng = np.random.default_rng(5)
    xs = []
    for _ in range(3):
        x = np.concatenate([rng.uniform(0.0, 0.8, L),
                            rng.integers(0, 3, L).astype(np.float64) + 0.5])
        xs.append(x)
    batch = ev.evaluate_batch(xs)
    for x, mb in zip(xs, batch):
        ms = ev(x)
        for k in ms:
            assert mb[k] == pytest.approx(ms[k], rel=1e-3, abs=1e-6), k


def test_cnn_tpu_path_derives_s_w_tile_from_pruned_weights():
    """On a TPUModel the CNN evaluator prunes tile-structured and MEASURES
    s_w_tile on the pruned weights (ROADMAP item; DESIGN.md §12) — no
    synthetic targets."""
    from repro.core import pruning
    from repro.core.perf_model import TPUModel
    cfg = reduce_config(RESNET18)
    params = cnn.init_params(cfg, RNG)
    images = jax.random.normal(RNG, (4, cfg.img_res, cfg.img_res, 3))
    tpu = TPUModel()
    ev = CNNEvaluator(cfg, params, images, tpu, budget=tpu.chip_budget,
                      dse_iters=150)
    assert ev.tiled
    x = np.full(2 * len(ev.prunable), 0.6)
    layers = ev.sparse_layers(x)
    pr = [l for l in layers if l.prunable]
    assert all(0.0 <= l.s_w_tile <= 1.0 for l in pr)
    assert any(l.s_w_tile > 0.0 for l in pr)
    # s_w_tile is the measured all-zero-tile fraction of the actual pruned
    # weights, cross-checked against pruning.tile_sparsity
    w = params[ev.names[0]]["w"]
    w2, frac = pruning.tile_prune(w, 0.6)
    assert float(frac) == pytest.approx(pruning.tile_sparsity(w2))
    assert pr[0].s_w_tile == pytest.approx(float(frac))
    # metrics flow through Eq. 6 with tile-granular compute skipping
    m = ev(x)
    assert m["thr"] > 0 and 0.0 <= m["dsp"] <= 1.0 + 1e-6
    m_dense = ev(np.zeros(2 * len(ev.prunable)))
    assert m["thr"] >= m_dense["thr"]
