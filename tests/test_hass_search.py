"""HASS end-to-end on a reduced ResNet-18 (the paper's Fig. 5 structure)."""
import jax
import numpy as np
import pytest

from repro.configs import reduce_config
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import incremental_dse
from repro.core.hass import CNNEvaluator, Lambdas, hass_search
from repro.core.perf_model import FPGAModel
from repro.models import cnn

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def evaluator():
    cfg = reduce_config(RESNET18)
    params = cnn.init_params(cfg, RNG)
    images = jax.random.normal(RNG, (8, cfg.img_res, cfg.img_res, 3))
    return CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                        dse_iters=400)


def test_evaluator_metric_contract(evaluator):
    m = evaluator(np.full(2 * len(evaluator.prunable), 0.4))
    assert 0.0 <= m["acc"] <= 1.0
    assert 0.0 <= m["spa"] <= 1.0
    assert m["thr"] > 0 and m["dsp"] <= 1.0 + 1e-6


def test_dense_proposal_gives_perfect_acc(evaluator):
    m = evaluator(np.zeros(2 * len(evaluator.prunable)))
    assert m["acc"] == 1.0
    assert m["spa"] < 0.45          # only natural relu zeros


def test_sparsity_increases_modeled_throughput(evaluator):
    lo = evaluator(np.zeros(2 * len(evaluator.prunable)))
    hi = evaluator(np.full(2 * len(evaluator.prunable), 0.7))
    assert hi["thr"] > lo["thr"]


def test_evaluate_batch_matches_serial(evaluator):
    """One vmapped prune+forward for B proposals == B serial jit calls (up
    to vmap-vs-jit float reassociation)."""
    L = len(evaluator.prunable)
    xs = [np.zeros(2 * L), np.full(2 * L, 0.4), np.full(2 * L, 0.75)]
    batch = evaluator.evaluate_batch(xs)
    assert len(batch) == 3
    for x, mb in zip(xs, batch):
        ms = evaluator(x)
        for k in ms:
            assert mb[k] == pytest.approx(ms[k], rel=1e-3, abs=1e-6), k


def test_batched_search_on_cnn_evaluator(evaluator):
    r = hass_search(evaluator, len(evaluator.prunable), iters=6,
                    s_max=0.9, seed=0, batch_size=3)
    assert len(r.trials) == 6
    assert 0.0 <= r.best_metrics["acc"] <= 1.0
    assert r.best_metrics["thr"] > 0


def test_metrics_pick_eq6_optimal_frontier_point(evaluator):
    """The hardware terms are scored at the frontier point maximizing the
    Eq. 6 combination — one DSE run, no re-search over budgets."""
    L = len(evaluator.prunable)
    x = np.full(2 * L, 0.5)
    m = evaluator(x)
    layers = evaluator.sparse_layers(x)
    f = incremental_dse(layers, evaluator.hw, evaluator.budget,
                        max_iters=evaluator.dse_iters).frontier
    thr_pts = f.thr * evaluator.hw.freq
    thr_norm = np.log2(1.0 + thr_pts / evaluator.dense_thr) / 4.0
    dsp = f.res / evaluator.budget
    lam = evaluator.lambdas
    scores = lam.thr * thr_norm - lam.dsp * dsp
    k = int(np.argmax(scores))
    assert m["thr"] == pytest.approx(float(thr_pts[k]))
    assert m["dsp"] == pytest.approx(float(dsp[k]))
    # never worse than always paying the full-budget endpoint (last point)
    assert scores[k] >= scores[-1] - 1e-15


def test_ragged_tail_batch_is_padded_to_one_compiled_shape(evaluator):
    """Batch-shape bucketing: a search whose last round is ragged pads it to
    the fixed batch shape, so no new vmapped executable is compiled, and the
    padded rows never reach tell_batch."""
    shapes_before = set(evaluator.batch_shapes)
    padded_before = evaluator.padded_batches
    r = hass_search(evaluator, len(evaluator.prunable), iters=8,
                    s_max=0.9, seed=1, batch_size=3)    # rounds 3 + 3 + 2
    assert len(r.trials) == 8                           # padding masked out
    assert evaluator.padded_batches > padded_before
    assert evaluator.batch_shapes - shapes_before <= {3}
    # a padded-round trial scores the same as the serial evaluator
    t = r.trials[-1]
    ms = evaluator(t.x)
    for k in ms:
        assert t.metrics[k] == pytest.approx(ms[k], rel=1e-3, abs=1e-6), k


@pytest.mark.slow
def test_hw_aware_search_beats_software_only(evaluator):
    """Fig. 5: at equal iteration budget, the hardware-aware objective finds
    higher computation efficiency (throughput/resource)."""
    kw = dict(iters=12, s_max=0.9, seed=0)
    hw = hass_search(evaluator, len(evaluator.prunable),
                     hardware_aware=True, **kw)
    sw = hass_search(evaluator, len(evaluator.prunable),
                     hardware_aware=False, **kw)
    assert hw.best_metrics["eff"] >= sw.best_metrics["eff"]
    # both retain usable accuracy proxies
    assert hw.best_metrics["acc"] >= 0.5
    assert len(hw.trials) == 12
    # running_best is monotone in score
    rb = hw.running_best("score")
    assert all(b >= a - 1e-12 for a, b in zip(rb, rb[1:]))
