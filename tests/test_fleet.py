"""Open-loop serving + fleet layer (DESIGN.md §14).

Load-bearing contracts:
  * on a backlogged trace whose decode length equals a bucket, the
    open-loop session issues exactly ``generate``'s model-call sequence,
    so greedy outputs match **bit for bit**;
  * ragged prompts pad to the chunk max and mask: a row's output is
    invariant to its batch companions; ``max_new=0`` emits nothing and
    completes at admission;
  * ``fleet.open_loop_schedule`` is the exact timing twin of
    ``ServeSession.serve_open_loop`` (identical admission/completion
    clocks — the property that lets the policy search trust the sim);
  * the fleet controller is deterministic and its accounting is sane;
    ``autoscale_policy_search`` returns an in-bounds policy that never
    scores worse than its own fallback rule.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serve.fleet import (AutoscalePolicy, FleetReport,
                               open_loop_schedule, simulate_fleet)
from repro.serve.serve_loop import (DEFAULT_BUCKETS, Request, ServeSession,
                                    requests_from_trace)
from repro.sim import autoscale_policy_search, mmpp_trace, poisson_trace
from repro.sim.trace import Trace, backlogged_trace

CFG = reduce_config(get_config("qwen3-0.6b"))


@pytest.fixture(scope="module")
def sess():
    api = build_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return ServeSession(api, params, batch_slots=2, S_max=32)


# --------------------------------------------------------------------- #
# Open-loop session vs closed-loop generate
# --------------------------------------------------------------------- #
def test_open_loop_backlogged_matches_generate_bit_exact(sess):
    """A backlogged trace with ``max_new`` equal to the admission quantum
    issues exactly ``generate``'s prefill/decode sequence: same model
    calls, same order, bitwise-equal greedy tokens."""
    tr = backlogged_trace(5, 8)        # 8 == smallest DEFAULT_BUCKET
    reqs = requests_from_trace(tr, vocab_size=CFG.vocab_size, prompt_len=6,
                               seed=0)
    ref = sess.generate([r.prompt for r in reqs], max_new=8)
    rep = sess.serve_open_loop(reqs, step_cycles=10.0, prefill_cycles=5.0)
    assert rep.outputs == ref
    assert [r.out for r in reqs] == ref
    assert rep.decode_steps == -(-len(reqs) // sess.B) * 7
    assert np.all(rep.completions > rep.admissions)


def test_generate_ragged_row_invariant_to_companions(sess):
    """Pad-to-max + mask: the long row's tokens must not depend on what
    shares its batch (regression for the pad_to/truncation bug where
    ragged chunks truncated every prompt to the shortest)."""
    rng = np.random.default_rng(3)
    long = rng.integers(0, CFG.vocab_size, size=9)
    short = rng.integers(0, CFG.vocab_size, size=4)
    alone = sess.generate([long], max_new=6)[0]
    with_short = sess.generate([long, short], max_new=6)[0]
    swapped = sess.generate([short, long], max_new=6)[1]
    assert with_short == alone
    assert swapped == alone
    # the short row really used only its own tokens: same output as padded
    # explicit batch of itself
    assert sess.generate([short, long], max_new=6)[0] == \
        sess.generate([short], max_new=6)[0]


def test_generate_max_new_zero_and_request_out(sess):
    """``max_new=0`` emits nothing (regression: it used to decode one
    token anyway) and ``Request.out`` fills in place per request."""
    rng = np.random.default_rng(4)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=5))
            for _ in range(2)]
    assert sess.generate(reqs, max_new=0) == [[], []]
    outs = sess.generate(reqs, max_new=3)
    assert [r.out for r in reqs] == outs
    assert all(len(o) == 3 for o in outs)
    # default_factory regression: fresh requests get distinct lists
    a, b = Request(prompt=np.array([1])), Request(prompt=np.array([2]))
    assert a.out == [] and a.out is not b.out


def test_open_loop_report_accounting(sess):
    """Mixed arrivals + a zero-length request: monotone clocks, queue
    waits, truncation to ``max_new``, slot reuse."""
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=6),
                    max_new=m, arrival=a)
            for m, a in ((5, 0.0), (0, 0.0), (8, 40.0), (3, 41.0))]
    rep = sess.serve_open_loop(reqs, step_cycles=10.0, prefill_cycles=5.0)
    assert np.all(rep.admissions >= rep.arrivals)
    assert np.all(rep.completions >= rep.admissions)
    assert np.array_equal(rep.queue_wait, rep.admissions - rep.arrivals)
    assert [len(o) for o in rep.outputs] == [5, 0, 8, 3]
    assert rep.completions[1] == rep.admissions[1]   # max_new=0
    assert rep.p50 <= rep.p99 <= rep.horizon
    with pytest.raises(ValueError, match="buckets"):
        sess.serve_open_loop(reqs, step_cycles=1.0, buckets=(8, 12))


# --------------------------------------------------------------------- #
# Fleet timing twin + controller
# --------------------------------------------------------------------- #
def test_open_loop_schedule_is_exact_timing_twin(sess):
    """The pure-timing twin reproduces the real session's admission and
    completion clocks bit for bit — on bursty arrivals, ragged decode
    lengths, and zero-length requests."""
    tr = poisson_trace(10, 5e-3, sizes=[4, 8, 16, 20], seed=1)
    reqs = requests_from_trace(tr, vocab_size=CFG.vocab_size, prompt_len=6,
                               seed=1)
    reqs[3].max_new = 0
    max_new = [r.max_new for r in reqs]
    rep = sess.serve_open_loop(reqs, step_cycles=7.0, prefill_cycles=3.0)
    adm, comp = open_loop_schedule(tr.arrivals, max_new, batch_slots=sess.B,
                                   step_cycles=7.0, prefill_cycles=3.0)
    assert np.array_equal(rep.admissions, adm)
    assert np.array_equal(rep.completions, comp)
    with pytest.raises(ValueError, match="buckets"):
        open_loop_schedule([0.0], [8], batch_slots=2, step_cycles=1.0,
                           buckets=(8, 20))


def test_simulate_fleet_static_accounting():
    tr = mmpp_trace(200, 1e-4, 5e-3, dwell_base=2e4, dwell_burst=1e4,
                    sizes=[8, 16], seed=0)
    kw = dict(batch_slots=4, step_cycles=10.0, prefill_cycles=30.0)
    reps = {r: simulate_fleet(tr, AutoscalePolicy.static(r), **kw)
            for r in (1, 3)}
    for r, rep in reps.items():
        assert isinstance(rep, FleetReport)
        assert np.all(rep.assignment >= 0) and np.all(rep.assignment < r)
        assert np.all(rep.completions >= rep.admissions)
        assert np.all(rep.latency >= 0)
        assert rep.replicas_max == r
        assert rep.replica_cycles > 0
        # static fleet: every replica active for the whole horizon
        assert rep.replica_cycles == pytest.approx(r * rep.horizon,
                                                   rel=1e-9)
    assert reps[3].p99 <= reps[1].p99
    # determinism
    again = simulate_fleet(tr, AutoscalePolicy.static(3), **kw)
    assert np.array_equal(again.assignment, reps[3].assignment)
    assert np.array_equal(again.completions, reps[3].completions)


def test_simulate_fleet_scales_up_and_down():
    """A burst sandwiched between sparse stretches: the controller must
    add replicas during the burst and shed them after, spending fewer
    replica-cycles than the static fleet of its own peak size."""
    sparse = np.arange(10) * 5e4
    burst = 6e5 + np.arange(120) * 15.0    # ~2x one replica's est capacity
    tail = 1.2e6 + np.arange(10) * 5e4
    arr = np.concatenate([sparse, burst, tail])
    tr = Trace(arr, np.full(len(arr), 8), kind="replay")
    kw = dict(batch_slots=4, step_cycles=10.0, prefill_cycles=30.0)
    pol = AutoscalePolicy(min_replicas=1, max_replicas=3,
                          scale_up_backlog=0.05, scale_down_backlog=0.04,
                          boundary_cycles=500.0)
    rep = simulate_fleet(tr, pol, **kw)
    static = simulate_fleet(tr, AutoscalePolicy.static(3), **kw)
    assert rep.replicas_max > 1                     # scaled up in the burst
    assert min(c for _, c in rep.timeline) == 1     # and back down
    assert rep.replica_cycles < static.replica_cycles
    assert rep.p99 <= static.p99 * (1 + 1e-9)


def test_serve_open_loop_deadline_sheds_and_accounts(sess):
    """Past-deadline requests shed at their admission round: no slot, no
    model call, ``completions == inf`` exactly, and the report's
    percentiles/horizon only see the served rows."""
    rng = np.random.default_rng(6)
    arr = np.cumsum(rng.exponential(20.0, 12))
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=5),
                    max_new=8, arrival=float(a),
                    deadline=float(a) + (50.0 if k % 3 == 0 else 1e9))
            for k, a in enumerate(arr)]
    rep = sess.serve_open_loop(reqs, step_cycles=30.0, prefill_cycles=90.0)
    assert rep.shed > 0 and rep.completed + rep.shed == 12
    assert np.all(np.isinf(rep.completions[rep.shed_mask]))
    assert np.all(np.isfinite(rep.completions[~rep.shed_mask]))
    # shed rows emitted nothing; served rows decoded fully
    outs = [len(o) for o in rep.outputs]
    assert all(n == 0 for n, s in zip(outs, rep.shed_mask) if s)
    assert all(n == 8 for n, s in zip(outs, rep.shed_mask) if not s)
    assert np.isfinite(rep.p99) and np.isfinite(rep.horizon)


def test_degraded_schedule_is_exact_timing_twin(sess):
    """A frontier-degraded bucket schedule (rung step-scale changes mid
    trace + per-request deadlines) replays twin-identical through the
    real serve path — the property that lets the chaos fleet's degraded
    epochs trust ``open_loop_schedule``."""
    rng = np.random.default_rng(8)
    n = 16
    arr = np.cumsum(rng.exponential(250.0, n)).astype(float)
    new = rng.integers(4, 20, n).astype(float)
    dls = arr + rng.uniform(8e2, 8e3, n)
    sched = [(0.0, 1.0), (float(arr[5]), 0.6), (float(arr[11]), 0.85)]
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=5),
                    max_new=int(new[i]), arrival=float(arr[i]),
                    deadline=float(dls[i])) for i in range(n)]
    rep = sess.serve_open_loop(reqs, step_cycles=25.0, prefill_cycles=75.0,
                               step_schedule=sched, switch_cycles=40.0)
    adm, comp = open_loop_schedule(arr, new, batch_slots=sess.B,
                                   step_cycles=25.0, prefill_cycles=75.0,
                                   deadlines=dls, step_schedule=sched,
                                   switch_cycles=40.0)
    assert np.array_equal(rep.admissions, adm)
    assert np.array_equal(rep.completions, comp)
    assert rep.switch_stalls == 2
    assert rep.shed + rep.completed == n
    with pytest.raises(ValueError, match="scale"):
        open_loop_schedule(arr, new, batch_slots=2, step_cycles=1.0,
                           step_schedule=[(0.0, 0.0)])


def test_autoscale_policy_search_smoke():
    tr = mmpp_trace(300, 1e-4, 8e-3, dwell_base=1e5, dwell_burst=4e4,
                    sizes=[8, 16], seed=2)
    pol, rep, base = autoscale_policy_search(
        tr, batch_slots=4, step_cycles=10.0, prefill_cycles=30.0,
        max_replicas=3, n_trials=6, seed=0)
    assert 1 <= pol.min_replicas <= pol.max_replicas == 3
    assert 0 < pol.scale_down_backlog < pol.scale_up_backlog
    assert set(base) == {1, 2, 3, "static_best"}
    p99_s, _ = base[base["static_best"]]
    # selection rule: feasible (no tail regression) else min-p99 fallback
    assert rep.p99 <= p99_s or \
        rep.p99 == min(r.p99 for r in [rep])
    # determinism: same seed, same winner
    pol2, rep2, _ = autoscale_policy_search(
        tr, batch_slots=4, step_cycles=10.0, prefill_cycles=30.0,
        max_replicas=3, n_trials=6, seed=0)
    assert pol2 == pol and rep2.p99 == rep.p99
