"""ParetoFrontier contract (DESIGN.md §10).

Every DSE run returns its full non-dominated (resource, throughput)
frontier with materializable per-point design state. The contract:
monotone + non-dominated, best-under-budget bit-exactly equal to the
single-point ``incremental_dse``/``incremental_dse_ref`` result, and every
materialized point reproducing its recorded (resource, throughput) without
re-running the search.
"""
import numpy as np
import pytest
from conftest import sparse_cnn_workload as _paper_stack

from repro.configs.paper_cnns import MOBILENETV3S, RESNET18
from repro.core.dse import incremental_dse, incremental_dse_ref
from repro.core.perf_model import (FPGAModel, LayerCost, TPUModel,
                                   pipeline_throughput)

HW = [(FPGAModel(), 12288.0), (TPUModel(), TPUModel().budget)]


def _random_stack(rng, L):
    return [LayerCost(f"l{i}", macs=int(rng.integers(0, 10 ** 7)),
                      m_dot=int(rng.integers(1, 4096)),
                      weight_count=1, act_in=1, act_out=1,
                      s_w=float(rng.uniform(0, 1.0)),
                      s_a=float(rng.uniform(0, 0.9)),
                      s_w_tile=float(rng.uniform(0, 0.5)),
                      prunable=bool(rng.integers(2)))
            for i in range(L)]


@pytest.mark.parametrize("hw,budget", HW, ids=["fpga", "tpu"])
def test_frontier_is_monotone_and_non_dominated(hw, budget):
    rng = np.random.default_rng(11)
    for trial in range(10):
        layers = _random_stack(rng, int(rng.integers(1, 20)))
        b = float(rng.integers(1, int(budget)))
        f = incremental_dse(layers, hw, b, max_iters=200).frontier
        assert len(f) >= 1
        # strictly increasing in both coordinates == non-dominated
        assert np.all(np.diff(f.res) > 0)
        assert np.all(np.diff(f.thr) > 0)
        assert f.spe.shape == (len(f), len(layers))
        assert f.n.shape == (len(f), len(layers))


@pytest.mark.parametrize("hw,budget", HW, ids=["fpga", "tpu"])
def test_best_under_budget_matches_dse_result_bit_exactly(hw, budget):
    """The frontier endpoint under the search budget IS the search result —
    so every consumer that used to re-run the DSE can read the frontier."""
    rng = np.random.default_rng(42)
    for trial in range(8):
        layers = _random_stack(rng, int(rng.integers(1, 20)))
        b = float(rng.integers(1, int(budget)))
        r = incremental_dse(layers, hw, b, max_iters=200)
        ref = incremental_dse_ref(layers, hw, b, max_iters=200)
        k = r.frontier.best_under(b)
        if k is None:        # minimal design already over this tiny budget
            assert r.frontier.res[0] > b
            continue
        assert r.frontier.res[k] == r.resource == ref.resource
        assert r.frontier.thr[k] == r.throughput == ref.throughput
        assert r.frontier.materialize(k) == r.designs == ref.designs


@pytest.mark.parametrize("cfg", [RESNET18, MOBILENETV3S],
                         ids=["resnet18", "mobilenetv3s"])
def test_best_under_budget_matches_on_paper_cnns(cfg):
    hw, budget = FPGAModel(), 8192.0
    layers = _paper_stack(cfg)
    r = incremental_dse(layers, hw, budget)
    k = r.frontier.best_under(budget)
    assert r.frontier.point(k) == (r.resource, r.throughput)
    assert r.frontier.materialize(k) == r.designs


@pytest.mark.parametrize("hw,budget", HW, ids=["fpga", "tpu"])
def test_materialized_points_reproduce_recorded_values(hw, budget):
    """Any frontier point rebuilds concrete DesignPoints whose modeled
    throughput and summed resource equal the recorded pair exactly."""
    layers = _paper_stack(RESNET18, seed=3)
    f = incremental_dse(layers, hw, budget).frontier
    for k in np.linspace(0, len(f) - 1, min(12, len(f))).astype(int):
        designs = f.materialize(int(k))
        thr = pipeline_throughput(layers, designs, hw)
        res = sum(hw.layer_resource(l, d) for l, d in zip(layers, designs))
        assert thr == f.thr[k]
        assert res == f.res[k]


def test_best_under_returns_none_below_minimal_design():
    hw = FPGAModel()
    layers = _paper_stack(RESNET18, seed=1)
    f = incremental_dse(layers, hw, 4096.0).frontier
    assert f.best_under(float(f.res[0])) == 0
    assert f.best_under(float(f.res[0]) - 1.0) is None


def test_select_maximizes_vectorized_score():
    hw = FPGAModel()
    layers = _paper_stack(RESNET18, seed=2)
    f = incremental_dse(layers, hw, 8192.0).frontier
    k = f.select(lambda res, thr: thr - 1e-7 * res)
    scores = f.thr - 1e-7 * f.res
    assert scores[k] == scores.max()


def test_frontier_trace_consistency():
    """Frontier points are drawn from the recorded search path: each one is
    either a trace row or the final trimmed result."""
    hw = FPGAModel()
    layers = _paper_stack(MOBILENETV3S, seed=5)
    r = incremental_dse(layers, hw, 4096.0)
    pts = set(r.trace) | {(r.resource, r.throughput)}
    for k in range(len(r.frontier)):
        assert r.frontier.point(k) in pts
