import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_ENABLE_X64", "0")

# Containers without hypothesis fall back to the fixed-seed stub so property
# tests still collect and run; test modules just `from hypothesis import ...`.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


def sparse_cnn_workload(cfg, seed=1):
    """Paper-CNN layer stack with per-layer sparsity stats in the paper's
    reported range (§VI) — the shared workload for the frontier and DP
    partitioning tests (benchmarks/dse_bench.py keeps a standalone copy with
    the same convention)."""
    import numpy as np

    from repro.core.perf_model import cnn_layer_costs

    rng = np.random.default_rng(seed)
    layers = cnn_layer_costs(cfg)
    for l in layers:
        l.s_w = float(rng.uniform(0.1, 0.8))
        l.s_a = float(rng.uniform(0.1, 0.6))
        l.s_w_tile = float(rng.uniform(0.0, 0.4))
    return layers
