import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_ENABLE_X64", "0")

# Containers without hypothesis fall back to the fixed-seed stub so property
# tests still collect and run; test modules just `from hypothesis import ...`.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
