import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_ENABLE_X64", "0")
