"""Fault injection, chaos fleet serving, and failure-aware search
(DESIGN.md §17).

Load-bearing contracts:
  * a ``FaultTrace`` is deterministic in its arrays (and ``inject_faults``
    in its seed): equal scenarios ⇒ byte-identical simulations;
  * the heap and calendar engines stay **bit-identical** under faults and
    their extended conservation law holds —
    ``busy + blocked + idle + down == horizon`` per node;
  * zero-fault scenarios (``faults=None`` vs ``FaultTrace.none()``) take
    the exact pre-fault code paths: reports match byte for byte;
  * the chaos fleet loses no request: every admitted request completes or
    is an accounted shed (``completions == inf`` exactly on
    ``shed_mask``), and crash/retry runs replay deterministically;
  * graceful degradation sheds strictly fewer requests than a
    non-degrading fleet at equal replica cost, and ``degradation_ladder``
    prices a ``DegradationPolicy``-valid ladder off the DSE frontier;
  * the failure-aware SLO search simulates every candidate under the
    fault set and reports per-scenario tails in ``fault_reports``.
"""
import numpy as np
import pytest
from conftest import sparse_cnn_workload

from repro.configs.paper_cnns import RESNET18
from repro.core.dse import degradation_ladder, partition_pipeline
from repro.core.perf_model import FPGAModel, LayerCost, TPUModel
from repro.serve.fleet import (AutoscalePolicy, DegradationPolicy,
                               RetryPolicy, open_loop_schedule,
                               simulate_fleet)
from repro.sim import (SLO, FaultTrace, autoscale_policy_search,
                       inject_faults, mmpp_trace, replica_loss,
                       request_rate, simulate_partition, zero_fault_trace)
from repro.sim.engine import _simulate_chain
from repro.sim.faults import NodeFaults
from repro.sim.slo import latency_percentile, slo_partition_search
from repro.sim.trace import Trace

KW = dict(batch_slots=4, step_cycles=10.0, prefill_cycles=30.0)


# --------------------------------------------------------------------- #
# FaultTrace construction, validation, determinism
# --------------------------------------------------------------------- #
def test_fault_trace_validation_and_canonical_order():
    with pytest.raises(ValueError, match="columns"):
        FaultTrace(crashes=[[0.0, 1.0]])
    with pytest.raises(ValueError, match="t_end > t_start"):
        FaultTrace(crashes=[[0.0, 5.0, 5.0]])
    with pytest.raises(ValueError, match=">= 0"):
        FaultTrace(slowdowns=[[-1.0, 0.0, 1.0, 0.5]])
    with pytest.raises(ValueError, match="positive"):
        FaultTrace(ici=[[0.0, 0.0, 1.0, 0.0]])
    ft = FaultTrace(crashes=[[1, 50.0, 60.0], [0, 10.0, 20.0],
                             [0, 5.0, 8.0]])
    # canonical (unit, t_start) order regardless of input order
    assert ft.crashes[:, 0].tolist() == [0, 0, 1]
    assert ft.crashes[:, 1].tolist() == [5.0, 10.0, 50.0]
    assert not ft.empty
    assert zero_fault_trace().empty and FaultTrace.none().empty
    rl = replica_loss(2, 100.0)
    assert rl.down_windows(2) == [(100.0, 1e30)]
    assert rl.down_windows(0) == []


def test_inject_faults_seeded_deterministic():
    kw = dict(crash_rate=2e-6, restart_mean=1e5, slow_rate=3e-6,
              slow_mean=5e4, slow_factor=0.4, n_hops=2, ici_rate=1e-6,
              ici_mean=1e5)
    a = inject_faults(3, 2e6, seed=7, **kw)
    b = inject_faults(3, 2e6, seed=7, **kw)
    c = inject_faults(3, 2e6, seed=8, **kw)
    assert np.array_equal(a.crashes, b.crashes)
    assert np.array_equal(a.slowdowns, b.slowdowns)
    assert np.array_equal(a.ici, b.ici)
    assert not (np.array_equal(a.crashes, c.crashes)
                and np.array_equal(a.slowdowns, c.slowdowns))
    assert not a.empty and a.kind == "injected"
    with pytest.raises(ValueError, match="n_units"):
        inject_faults(0, 1e6)
    with pytest.raises(ValueError, match="horizon"):
        inject_faults(1, 0.0)


def test_node_faults_delay_and_slowdown():
    fx = NodeFaults(down=[[(10.0, 25.0), (30.0, 40.0)]],
                    slow=[[(40.0, 100.0, 0.5), (40.0, 100.0, 0.5)]])
    # service begun inside a down window starts at its end
    occ, dn = fx(0, 12.0, 8.0)
    assert dn == 13.0 and occ == 13.0 + 8.0
    # a delayed start landing in a later window keeps sliding — and the
    # compounded slowdown at the effective start divides the rate by 4
    occ, dn = fx(0, 32.0, 8.0)
    assert dn == 8.0 and occ == 8.0 + 8.0 / 0.25
    # clean start, no windows active
    assert fx(0, 0.0, 8.0) == (8.0, 0.0)


# --------------------------------------------------------------------- #
# Engine bit-identity + conservation under faults
# --------------------------------------------------------------------- #
def _rand_chain(rng, n_nodes):
    n = int(rng.integers(40, 120))
    arr = np.sort(rng.uniform(0, 5e4, n))
    sizes = rng.integers(1, 16, n).astype(np.int64)
    rates = rng.uniform(5e-3, 5e-2, n_nodes)
    service = [(lambda r: (lambda s: s / r))(r) for r in rates]
    caps = [10**9] + [int(rng.integers(1, 4)) for _ in range(n_nodes - 1)]
    return arr, sizes, service, caps


def test_engines_bit_identical_and_conserve_under_faults():
    rng = np.random.default_rng(0)
    for trial in range(6):
        m = int(rng.integers(1, 5))
        arr, sizes, service, caps = _rand_chain(rng, m)
        ft = inject_faults(m, 6e4, crash_rate=3e-4, restart_mean=2e3,
                           slow_rate=3e-4, slow_mean=3e3, slow_factor=0.5,
                           seed=trial)
        fx = NodeFaults(down=[ft.down_windows(u) for u in range(m)],
                        slow=[ft.slow_windows(u) for u in range(m)])
        heap = _simulate_chain(arr, sizes, service, caps, "heap", fx)
        cal = _simulate_chain(arr, sizes, service, caps, "calendar", fx)
        comp_h, busy_h, blk_h, idle_h, qm_h, qx_h, down_h = heap
        comp_c, busy_c, blk_c, idle_c, qm_c, qx_c, down_c = cal
        assert np.array_equal(comp_h, comp_c)
        for a, b in zip(heap[1:], cal[1:]):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert any(d > 0 for d in down_h), "fault set never fired"
        horizon = comp_h.max()
        for k in range(m):
            total = busy_h[k] + blk_h[k] + idle_h[k] + down_h[k]
            assert total == pytest.approx(horizon, rel=1e-12)


def test_zero_fault_chain_matches_fx_none_bit_exact():
    rng = np.random.default_rng(1)
    for m in (1, 3):
        arr, sizes, service, caps = _rand_chain(rng, m)
        nul = NodeFaults(down=[[] for _ in range(m)],
                         slow=[[] for _ in range(m)])
        for eng in ("heap", "calendar"):
            ref = _simulate_chain(arr, sizes, service, caps, eng)
            got = _simulate_chain(arr, sizes, service, caps, eng, nul)
            assert np.array_equal(ref[0], got[0])
            for a, b in zip(ref[1:], got[1:]):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_simulate_partition_faults_perturb_and_account():
    layers = sparse_cnn_workload(RESNET18, seed=0)
    tpu = TPUModel(chips=4)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=16, dse_iters=80, objective="maxmin")
    rate = request_rate(p.steady_throughput, 0.4, 16)
    tr = mmpp_trace(200, 0.6 * rate, 3 * rate, dwell_base=4 / rate,
                    dwell_burst=1 / rate, sizes=16, seed=0)
    clean = simulate_partition(layers, tpu, p, tr)
    horizon = float(clean.completions.max())
    ft = inject_faults(4, horizon, crash_rate=4.0 / horizon,
                       restart_mean=horizon / 30, slow_rate=4.0 / horizon,
                       slow_mean=horizon / 20, slow_factor=0.4,
                       n_hops=3, ici_rate=2.0 / horizon,
                       ici_mean=horizon / 20, seed=1)
    hurt = simulate_partition(layers, tpu, p, tr, faults=ft)
    assert float(hurt.down.sum()) > 0
    assert hurt.p99 >= clean.p99
    # zero-fault scenario: byte-identical to faults=None
    same = simulate_partition(layers, tpu, p, tr, faults=zero_fault_trace())
    assert np.array_equal(same.completions, clean.completions)
    assert np.array_equal(same.busy, clean.busy)
    assert np.array_equal(same.down, clean.down)
    # determinism: same FaultTrace, same report
    again = simulate_partition(layers, tpu, p, tr, faults=ft)
    assert np.array_equal(again.completions, hurt.completions)
    assert np.array_equal(again.down, hurt.down)


def test_latency_percentile_zero_completions_raises():
    layers = sparse_cnn_workload(RESNET18, seed=0)
    tpu = TPUModel(chips=2)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=16, dse_iters=60, objective="maxmin")
    tr = Trace(np.array([0.0]), np.array([16]), kind="replay")
    rep = simulate_partition(layers, tpu, p, tr)
    rep.latency = rep.latency[:0]
    with pytest.raises(ValueError, match="zero completions"):
        latency_percentile(rep)


# --------------------------------------------------------------------- #
# Chaos fleet: validation, conservation, determinism
# --------------------------------------------------------------------- #
def test_fleet_validation_errors():
    tr = mmpp_trace(50, 1e-4, 5e-3, dwell_base=2e4, dwell_burst=1e4,
                    sizes=[8], seed=0)
    empty = Trace(np.array([]), np.array([]), kind="replay")
    with pytest.raises(ValueError, match="non-empty"):
        simulate_fleet(empty, AutoscalePolicy.static(1), **KW)
    with pytest.raises(ValueError, match="batch_slots"):
        simulate_fleet(tr, AutoscalePolicy.static(1), batch_slots=0,
                       step_cycles=10.0)
    with pytest.raises(ValueError, match="deadline_cycles"):
        simulate_fleet(tr, AutoscalePolicy.static(1), deadline_cycles=0.0,
                       **KW)
    with pytest.raises(ValueError, match="batch_slots"):
        open_loop_schedule([0.0], [8], batch_slots=0, step_cycles=1.0)
    for bad in (dict(min_replicas=0), dict(max_replicas=0),
                dict(min_replicas=3, max_replicas=2),
                dict(scale_up_backlog=0.0),
                dict(scale_up_backlog=1.0, scale_down_backlog=1.5),
                dict(scale_down_backlog=-0.1), dict(boundary_cycles=0.0),
                dict(admit_depth=0.0), dict(spinup_cycles=-1.0)):
        with pytest.raises(ValueError):
            AutoscalePolicy(**bad)
    for bad in (dict(ladder=()), dict(ladder=(0.9,)),
                dict(ladder=(1.0, 0.5, 0.7)), dict(ladder=(1.0, 0.0)),
                dict(degrade_backlog=0.0),
                dict(recover_backlog=9.0, degrade_backlog=8.0),
                dict(dwell_cycles=-1.0), dict(switch_cycles=-1.0)):
        with pytest.raises(ValueError):
            DegradationPolicy(**bad)


def test_fleet_zero_fault_scenario_bit_identical():
    tr = mmpp_trace(300, 1e-4, 8e-3, dwell_base=1e5, dwell_burst=4e4,
                    sizes=[8, 16], seed=2)
    pol = AutoscalePolicy(min_replicas=1, max_replicas=3,
                          scale_up_backlog=1.0, scale_down_backlog=0.2)
    ref = simulate_fleet(tr, pol, **KW)
    got = simulate_fleet(tr, pol, faults=zero_fault_trace(), **KW)
    for f in ("admissions", "completions", "latency", "assignment",
              "routed_at", "shed_mask", "retries"):
        assert np.array_equal(getattr(ref, f), getattr(got, f)), f
    assert got.replica_cycles == ref.replica_cycles
    assert got.shed == 0 and got.retries.sum() == 0


def test_fleet_crash_retry_deterministic_and_conserving():
    tr = mmpp_trace(600, 2e-4, 1.5e-2, dwell_base=3e5, dwell_burst=8e4,
                    sizes=[8, 16], seed=0)
    peak = float(np.median(tr.arrivals))
    ft = replica_loss(1, peak, peak + 5e5)
    a = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft, **KW)
    b = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft, **KW)
    for f in ("admissions", "completions", "latency", "assignment",
              "routed_at", "shed_mask", "retries"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.retries.sum() > 0, "crash at peak never forced a re-dispatch"
    # conservation: every request completes or is an accounted shed
    assert np.all(np.isfinite(a.completions[~a.shed_mask]))
    assert np.all(np.isinf(a.completions[a.shed_mask]))
    assert a.completed + a.shed == len(tr.arrivals)
    clean = simulate_fleet(tr, AutoscalePolicy.static(2), **KW)
    assert a.p99 > clean.p99


def test_fleet_retry_budget_sheds_not_loses():
    """A never-restarting crash of the only replica: all in-flight and
    later requests must exhaust their retry budget and shed — none lost,
    none stuck."""
    arr = np.arange(40) * 1e3
    tr = Trace(arr, np.full(40, 8), kind="replay")
    ft = replica_loss(0, 5e3)
    rep = simulate_fleet(tr, AutoscalePolicy.static(1), faults=ft,
                         retry=RetryPolicy(max_retries=1,
                                           backoff_base=1e3), **KW)
    assert rep.shed > 0
    assert rep.completed + rep.shed == 40
    assert np.all(np.isinf(rep.completions[rep.shed_mask]))
    assert np.all(rep.retries[rep.shed_mask] >= 1)


def test_fleet_deadline_sheds_and_filters_percentiles():
    arr = np.arange(60) * 10.0            # far above one replica's rate
    tr = Trace(arr, np.full(60, 16), kind="replay")
    rep = simulate_fleet(tr, AutoscalePolicy.static(1),
                         deadline_cycles=2e3, **KW)
    assert rep.shed > 0 and rep.completed > 0
    # shed requests never count toward the tail
    lat = rep.latency[~rep.shed_mask]
    assert rep.p99 <= np.max(lat)
    assert np.isfinite(rep.p99)


def test_degradation_sheds_strictly_fewer_at_equal_cost():
    tr = mmpp_trace(2000, 2e-4, 2e-2, dwell_base=2e5, dwell_burst=1.5e5,
                    sizes=[8, 16], seed=0)
    peak = float(np.median(tr.arrivals))
    ft = replica_loss(1, peak, peak + 2e6)
    kw = dict(batch_slots=8, step_cycles=100.0, prefill_cycles=300.0)
    plain = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft,
                           deadline_cycles=2e5, **kw)
    deg = DegradationPolicy(ladder=(1.0, 0.6, 0.35), degrade_backlog=3.0,
                            recover_backlog=0.5, dwell_cycles=1e5,
                            switch_cycles=1e4)
    soft = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft,
                          deadline_cycles=2e5, degradation=deg, **kw)
    assert soft.shed < plain.shed
    assert soft.replica_cycles <= plain.replica_cycles * (1 + 1e-9)
    # the controller actually moved down the ladder and back
    rungs = [r for _, r in soft.rung_timeline]
    assert max(rungs) >= 1 and rungs[0] == 0
    # determinism of the degraded run
    again = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft,
                           deadline_cycles=2e5, degradation=deg, **kw)
    assert np.array_equal(again.completions, soft.completions)
    assert again.rung_timeline == soft.rung_timeline


# --------------------------------------------------------------------- #
# Degradation ladder off the DSE frontier
# --------------------------------------------------------------------- #
def test_degradation_ladder_prices_valid_policy():
    hw = FPGAModel()
    rng = np.random.default_rng(0)
    layers = [LayerCost(f"l{i}", macs=int(rng.integers(1e5, 1e6)),
                        m_dot=64, weight_count=1, act_in=1, act_out=1,
                        s_w=float(rng.uniform(0.2, 0.6)))
              for i in range(6)]
    rungs = degradation_ladder(layers, hw, budget=2000.0,
                               s_extra=(0.0, 0.15, 0.3))
    assert rungs[0].step_scale == 1.0 and rungs[0].s_extra == 0.0
    assert all(b.step_scale <= a.step_scale
               for a, b in zip(rungs, rungs[1:]))
    assert all(b.throughput >= a.throughput
               for a, b in zip(rungs, rungs[1:]))
    # the ladder drops straight into the serving-side policy
    DegradationPolicy(ladder=tuple(r.step_scale for r in rungs))
    for bad in ((0.1, 0.2), (0.0, 0.2, 0.2), (0.0, 1.0), ()):
        with pytest.raises(ValueError):
            degradation_ladder(layers, hw, 2000.0, s_extra=bad)


# --------------------------------------------------------------------- #
# Failure-aware SLO / autoscale search
# --------------------------------------------------------------------- #
def test_slo_partition_search_failure_aware():
    layers = sparse_cnn_workload(RESNET18, seed=0)
    tpu = TPUModel(chips=4)
    mm = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                            batch=16, dse_iters=80, objective="maxmin")
    rate = request_rate(mm.steady_throughput, 0.4, 16)
    tr = mmpp_trace(200, 0.6 * rate, 3 * rate, dwell_base=4 / rate,
                    dwell_burst=1 / rate, sizes=16, seed=0)
    rep0 = simulate_partition(layers, tpu, mm, tr)
    slo = SLO(target=rep0.p99 * 4.0)
    horizon = float(rep0.completions.max())
    ft = inject_faults(4, horizon, slow_rate=6.0 / horizon,
                       slow_mean=horizon / 10, slow_factor=0.3, seed=2)
    r = slo_partition_search(layers, tpu, tpu.chip_budget, slo=slo,
                             trace=tr, n_parts=4, batch=16, dse_iters=80,
                             faults=ft)
    assert r.objective == "slo"
    assert r.fault_reports is not None and len(r.fault_reports) == 1
    assert float(r.fault_reports[0].down.sum()) >= 0
    # an empty fault set leaves the pristine result (and no fault_reports)
    blind = slo_partition_search(layers, tpu, tpu.chip_budget, slo=slo,
                                 trace=tr, n_parts=4, batch=16,
                                 dse_iters=80)
    zero = slo_partition_search(layers, tpu, tpu.chip_budget, slo=slo,
                                trace=tr, n_parts=4, batch=16,
                                dse_iters=80, faults=zero_fault_trace())
    assert zero.cuts == blind.cuts and zero.fault_reports is None
    assert np.array_equal(zero.sim_report.completions,
                          blind.sim_report.completions)


def test_autoscale_policy_search_failure_aware_smoke():
    tr = mmpp_trace(400, 2e-4, 1.2e-2, dwell_base=2e5, dwell_burst=8e4,
                    sizes=[8, 16], seed=1)
    peak = float(np.median(tr.arrivals))
    ft = replica_loss(0, peak, peak + 8e5)
    pol, rep, base = autoscale_policy_search(
        tr, batch_slots=4, step_cycles=10.0, prefill_cycles=30.0,
        max_replicas=3, n_trials=8, seed=0, faults=ft,
        deadline_cycles=3e5)
    assert 1 <= pol.min_replicas <= pol.max_replicas == 3
    p99_s, _ = base[base["static_best"]]
    assert rep.completed + rep.shed == 400
    # determinism under the fault scenario
    pol2, rep2, _ = autoscale_policy_search(
        tr, batch_slots=4, step_cycles=10.0, prefill_cycles=30.0,
        max_replicas=3, n_trials=8, seed=0, faults=ft,
        deadline_cycles=3e5)
    assert pol2 == pol
    assert np.array_equal(rep2.completions, rep.completions)
