"""Batched HASS search engine: TPE ask_batch/tell_batch and the
``hass_search(batch_size=...)`` frontier (DESIGN.md §8).

The contract: batch_size=1 replays the serial search trial-for-trial at a
fixed seed (the serial loop is the degenerate batch), larger batches cover
the same number of trials, and the TPE batch API is RNG-compatible with the
serial ask/tell stream.
"""
import numpy as np
import pytest

from repro.core.hass import hass_search
from repro.core.tpe import TPE


def _tpe(seed=0, dim=3):
    return TPE(lo=np.zeros(dim), hi=np.ones(dim), seed=seed)


def synth_eval(x):
    """Deterministic, hardware-free metric dict (isolates engine plumbing
    from jit numerics)."""
    return {"acc": float(np.cos(2.0 * x).mean()),
            "spa": float(np.mean(x)),
            "thr": 1.0 + float(np.sum(x)),
            "thr_norm": float(np.tanh(np.mean(x))),
            "dsp": float(np.mean(x) ** 2)}


class CountingBatchEval:
    """Per-proposal evaluate plus a batch hook, with call accounting."""

    def __init__(self):
        self.batch_calls = 0
        self.single_calls = 0

    def __call__(self, x):
        self.single_calls += 1
        return synth_eval(x)

    def evaluate_batch(self, xs):
        self.batch_calls += 1
        return [synth_eval(x) for x in xs]


# --------------------------------------------------------------------- #
# TPE batch API
# --------------------------------------------------------------------- #
def test_ask_batch_of_one_matches_serial_ask():
    a, b = _tpe(seed=5), _tpe(seed=5)
    for _ in range(15):
        (xa,) = a.ask_batch(1)
        xb = b.ask()
        assert np.array_equal(xa, xb)
        y = float(np.sum(xa))
        a.tell_batch([xa], [y])
        b.tell(xb, y)
    assert np.array_equal(a.best[0], b.best[0]) and a.best[1] == b.best[1]


def test_ask_batch_proposals_are_diverse_and_in_bounds():
    t = _tpe(seed=1, dim=4)
    for _ in range(12):          # past startup so the Parzen model is live
        x = t.ask()
        t.tell(x, float(-np.sum((x - 0.3) ** 2)))
    xs = t.ask_batch(8)
    assert len(xs) == 8
    flat = np.stack(xs)
    assert np.all(flat >= t.lo) and np.all(flat <= t.hi)
    assert len({tuple(np.round(x, 12)) for x in xs}) == 8


def test_tell_batch_length_mismatch_raises():
    t = _tpe()
    with pytest.raises(ValueError):
        t.tell_batch([np.zeros(3)], [1.0, 2.0])


# --------------------------------------------------------------------- #
# Constant-liar protocol (DESIGN.md §12)
# --------------------------------------------------------------------- #
def _seeded_model(seed=3, dim=4, n=14):
    t = _tpe(seed=seed, dim=dim)
    for _ in range(n):
        x = t.ask()
        t.tell(x, float(-np.sum((x - 0.4) ** 2)))
    return t


def test_constant_liar_batch_replays_at_fixed_seed():
    a = _seeded_model(seed=7)
    b = _seeded_model(seed=7)
    xa = a.ask_batch(6, liar="min")
    xb = b.ask_batch(6, liar="min")
    for p, q in zip(xa, xb):
        assert np.array_equal(p, q)


def test_constant_liar_leaves_observations_untouched():
    t = _seeded_model()
    xs_before = [x.copy() for x in t.xs]
    ys_before = list(t.ys)
    t.ask_batch(5, liar="min")
    assert len(t.xs) == len(xs_before) and t.ys == ys_before
    for p, q in zip(t.xs, xs_before):
        assert np.array_equal(p, q)


def test_constant_liar_preserves_rng_stream_position():
    """Model refits consume no RNG, so the draw AFTER a batch is identical
    whichever protocol proposed the batch — fixed-seed searches stay
    replayable across the liar knob."""
    a = _seeded_model(seed=11)
    b = _seeded_model(seed=11)
    a.ask_batch(5, liar="min")
    b.ask_batch(5, liar=None)
    assert np.array_equal(a.ask(), b.ask())


def test_constant_liar_changes_proposals_vs_independent():
    a = _seeded_model(seed=2)
    b = _seeded_model(seed=2)
    xs_l = a.ask_batch(6, liar="min")
    xs_i = b.ask_batch(6, liar=None)
    assert np.array_equal(xs_l[0], xs_i[0])      # first member: same model
    assert any(not np.array_equal(p, q) for p, q in zip(xs_l[1:], xs_i[1:]))


def test_constant_liar_single_member_is_plain_ask():
    a = _seeded_model(seed=5)
    b = _seeded_model(seed=5)
    (xa,) = a.ask_batch(1, liar="min")
    assert np.array_equal(xa, b.ask())


def test_constant_liar_startup_batch_matches_legacy():
    a = _tpe(seed=9)
    b = _tpe(seed=9)
    a.tell(np.full(3, 0.5), 1.0)     # 1 obs, still pre-startup
    b.tell(np.full(3, 0.5), 1.0)
    xs_l = a.ask_batch(4, liar="min")
    xs_i = b.ask_batch(4, liar=None)
    for p, q in zip(xs_l, xs_i):
        assert np.array_equal(p, q)


def test_unknown_liar_mode_raises():
    with pytest.raises(ValueError):
        _tpe().ask_batch(3, liar="median")


def test_hass_search_passes_liar_through():
    kw = dict(iters=18, seed=6, batch_size=5)
    r_l = hass_search(synth_eval, 4, liar="min", **kw)
    r_i = hass_search(synth_eval, 4, liar=None, **kw)
    assert len(r_l.trials) == len(r_i.trials) == 18
    # post-startup rounds diverge between protocols
    assert any(not np.array_equal(a.x, b.x)
               for a, b in zip(r_l.trials[10:], r_i.trials[10:]))


# --------------------------------------------------------------------- #
# Batched hass_search
# --------------------------------------------------------------------- #
def test_batch_size_one_reproduces_serial_search_trial_for_trial():
    kw = dict(iters=24, seed=9, s_max=0.9)
    serial = hass_search(synth_eval, 4, **kw)
    batched = hass_search(synth_eval, 4, batch_size=1, **kw)
    assert len(serial.trials) == len(batched.trials) == 24
    for ts, tb in zip(serial.trials, batched.trials):
        assert np.array_equal(ts.x, tb.x)
        assert ts.score == tb.score
        assert ts.metrics == tb.metrics
    assert serial.best_score == batched.best_score
    assert np.array_equal(serial.best_x, batched.best_x)


def test_batched_search_uses_evaluate_batch_and_covers_all_trials():
    ev = CountingBatchEval()
    r = hass_search(ev, 4, iters=20, seed=0, batch_size=6)
    assert len(r.trials) == 20
    assert ev.batch_calls == 4          # ceil(20/6) rounds: 6+6+6+2
    assert ev.single_calls == 0
    assert r.best_score == max(t.score for t in r.trials)
    # running_best stays monotone across batch boundaries
    rb = r.running_best("score")
    assert all(b >= a - 1e-12 for a, b in zip(rb, rb[1:]))


def test_batched_search_falls_back_to_per_proposal_evaluate():
    calls = []

    def ev(x):
        calls.append(x)
        return synth_eval(x)

    r = hass_search(ev, 3, iters=10, seed=2, batch_size=4)
    assert len(r.trials) == 10 and len(calls) == 10


def test_batched_search_rejects_bad_batch_size():
    with pytest.raises(ValueError):
        hass_search(synth_eval, 3, iters=4, batch_size=0)


def test_hardware_aware_flag_respected_in_batched_scores():
    kw = dict(iters=16, seed=4, batch_size=5)
    hw = hass_search(synth_eval, 3, hardware_aware=True, **kw)
    sw = hass_search(synth_eval, 3, hardware_aware=False, **kw)
    for t in sw.trials:
        m = t.metrics
        assert t.score == pytest.approx(m["acc"] + 0.3 * m["spa"])
    for t in hw.trials:
        m = t.metrics
        assert t.score == pytest.approx(
            m["acc"] + 0.3 * m["spa"] + 0.5 * m["thr_norm"] - 0.3 * m["dsp"])
