"""Minimal, fixed-seed stand-in for ``hypothesis`` so the suite collects and
runs in containers that don't ship it.

Implements exactly the surface this repo's tests use:

  * ``strategies.floats(lo, hi)`` / ``integers(lo, hi)`` / ``sampled_from``
    / ``booleans``
  * ``@settings(max_examples=N, deadline=None)``
  * ``@given(**kwargs)`` — runs the test once per example with kwargs drawn
    from the strategies

Sampling is deterministic (seed derived from the test name) and always
includes the boundary examples first (lo/hi for floats and integers, first
element for sampled_from, both booleans), which is where these property
tests historically catch regressions. ``tests/conftest.py`` installs this
module as ``sys.modules["hypothesis"]`` only when the real package is
absent, so test modules use the plain ``from hypothesis import given,
settings, strategies as st`` form either way.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
from typing import Any, Callable, List

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A sampler plus the deterministic boundary examples tried first."""

    def __init__(self, sample: Callable[[np.random.Generator], Any],
                 boundary: List[Any]):
        self._sample = sample
        self.boundary = list(boundary)

    def sample(self, rng: np.random.Generator) -> Any:
        return self._sample(rng)


class strategies:
    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                         [lo, hi, 0.5 * (lo + hi)])

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 100) -> _Strategy:
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)), [lo, hi])

    @staticmethod
    def sampled_from(values) -> _Strategy:
        vals = list(values)
        return _Strategy(lambda rng: vals[int(rng.integers(len(vals)))],
                         vals[:2])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)), [False, True])


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may wrap @given (the usual order), tagging the
            # wrapper, or be applied inside it (tagging fn, copied onto the
            # wrapper by functools.wraps) — so read from the wrapper.
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = int.from_bytes(
                hashlib.sha256(fn.__name__.encode()).digest()[:4], "little")
            rng = np.random.default_rng(seed)
            names = list(strats)
            # boundary grid first (one axis at a time off a boundary base),
            # then random examples up to max_examples
            examples: List[dict] = []
            base = {k: s.boundary[0] for k, s in strats.items()}
            examples.append(dict(base))
            for k in names:
                for b in strats[k].boundary[1:]:
                    examples.append({**base, k: b})
            while len(examples) < n:
                examples.append({k: s.sample(rng) for k, s in strats.items()})
            for ex in examples[:max(n, 1)]:
                try:
                    fn(*args, **ex, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on stub-hypothesis example "
                        f"{ex}: {e}") from e
        # pytest must not try to fixture-inject the strategy params
        wrapper.__signature__ = inspect.Signature([
            p for p in inspect.signature(fn).parameters.values()
            if p.name not in strats])
        return wrapper
    return deco
