"""Deployment simulator (DESIGN.md §13): trace generators, the event
engine, the sim-vs-analytic saturation contract, and the SLO-aware
partition search.

Load-bearing contracts:
  * under a backlogged trace the simulator's steady completion rate equals
    the analytic model within ``SIM_TOL`` — ``steady_throughput`` in
    spatial mode (fuzzed over workloads, chip counts, objectives, and
    heterogeneous budgets) and the amortized temporal ``throughput`` when
    the request size is the partition batch;
  * a single resident partition incurs zero switch stalls (regression:
    the P - 1 switch accounting has no P = 1 term);
  * backpressure respects the finite queue depth; latency is bounded
    below by the no-wait service path;
  * ``objective="slo"`` reduces to the max-min pick when the SLO does not
    bind and returns a feasible (or least-violating) candidate otherwise.
"""
import numpy as np
import pytest
from conftest import sparse_cnn_workload
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduce_config
from repro.configs.paper_cnns import MOBILENETV3S, RESNET18
from repro.core.dse import partition_pipeline
from repro.core.hass import Lambdas, hass_search
from repro.core.perf_model import (FPGAModel, TPUModel, lm_block_bounds,
                                   lm_layer_costs)
from repro.serve.serve_loop import requests_from_trace
from repro.sim import (SIM_TOL, SLO, Trace, backlogged_trace, bucket_sizes,
                       diurnal_trace, mmpp_trace, poisson_trace,
                       replay_trace, request_rate, saturation_throughput,
                       simulate_partition)
from repro.sim.slo import latency_percentile, slo_partition_search


def _sparse_lm_stack(arch: str, seed: int):
    cfg = reduce_config(get_config(arch))
    layers = lm_layer_costs(cfg, seq_len=64)
    rng = np.random.default_rng(seed)
    for l in layers:
        if l.prunable:
            l.s_w = l.s_w_tile = float(rng.uniform(0.0, 0.8))
    return layers


# --------------------------------------------------------------------- #
# Trace generators
# --------------------------------------------------------------------- #
def test_traces_are_seed_deterministic_and_well_formed():
    for make in (lambda s: poisson_trace(300, 2e-5, sizes=8, seed=s),
                 lambda s: mmpp_trace(300, 1e-5, 5e-5, dwell_base=1e6,
                                      dwell_burst=2e5, sizes=8, seed=s),
                 lambda s: diurnal_trace(300, 1e-5, 4e-5, 1e7, sizes=8,
                                         seed=s)):
        a, b, c = make(0), make(0), make(1)
        assert np.array_equal(a.arrivals, b.arrivals)
        assert np.array_equal(a.sizes, b.sizes)
        assert not np.array_equal(a.arrivals, c.arrivals)
        assert np.all(np.diff(a.arrivals) >= 0)
        assert np.all(a.sizes >= 1)
        assert len(a) == 300


def test_poisson_trace_hits_its_rate():
    tr = poisson_trace(4000, 3e-5, seed=0)
    assert len(tr) / tr.span == pytest.approx(3e-5, rel=0.1)


def test_size_specs_constant_choice_and_weighted():
    rng_sizes = poisson_trace(200, 1e-5, sizes=16, seed=0).sizes
    assert np.all(rng_sizes == 16)
    choice = poisson_trace(200, 1e-5, sizes=[8, 32], seed=0).sizes
    assert set(np.unique(choice)) <= {8, 32}
    weighted = poisson_trace(400, 1e-5, sizes=((8, 32), (0.9, 0.1)),
                             seed=0).sizes
    assert np.mean(weighted == 8) > 0.7


def test_bucket_sizes_pad_up_rule():
    out = bucket_sizes(np.array([1, 8, 9, 33, 64, 65, 200]), [8, 32, 64])
    assert list(out) == [8, 8, 32, 64, 64, 128, 256]
    with pytest.raises(ValueError):
        bucket_sizes(np.array([1]), [])
    tr = replay_trace([0.0, 1.0], [3, 40]).bucketize([8, 32, 64])
    assert list(tr.sizes) == [8, 64]


def test_trace_scaling_and_offered_load():
    tr = poisson_trace(500, 1e-5, sizes=4, seed=0)
    fast = tr.scaled(2.0)
    assert fast.offered_load == pytest.approx(2 * tr.offered_load)
    assert np.array_equal(fast.sizes, tr.sizes)
    with pytest.raises(ValueError):
        tr.scaled(0.0)
    assert replay_trace([5.0, 5.0], 2).offered_load == float("inf")


def test_trace_validation():
    with pytest.raises(ValueError, match="nondecreasing"):
        Trace(np.array([1.0, 0.0]), np.array([1, 1]))
    with pytest.raises(ValueError, match="sizes"):
        Trace(np.array([0.0, 1.0]), np.array([1, 0]))
    with pytest.raises(ValueError, match="length"):
        Trace(np.array([0.0]), np.array([1, 1]))


# --------------------------------------------------------------------- #
# Sim-vs-analytic saturation contract
# --------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), chips=st.integers(2, 4),
       objective=st.sampled_from(["sum", "maxmin"]),
       workload=st.sampled_from(["cnn", "lm"]))
def test_property_spatial_saturation_matches_steady_throughput(
        seed, chips, objective, workload):
    """The subsystem's contract: simulated saturation == analytic
    ``steady_throughput`` within SIM_TOL on randomized partitions."""
    if workload == "cnn":
        layers = sparse_cnn_workload(MOBILENETV3S, seed=seed)
        cut_points = None
    else:
        layers = _sparse_lm_stack("qwen3-0.6b", seed)
        cut_points = lm_block_bounds(layers)
    tpu = TPUModel(chips=chips)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=chips,
                           batch=32, dse_iters=80, objective=objective,
                           cut_points=cut_points)
    sat = saturation_throughput(layers, tpu, p, n_requests=64)
    assert sat == pytest.approx(p.steady_throughput, rel=SIM_TOL)


def test_spatial_saturation_matches_on_heterogeneous_chips():
    layers = sparse_cnn_workload(RESNET18, seed=3)
    tpu = TPUModel(chips=3, chip_lanes=(512.0, 256.0, 384.0))
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=32, dse_iters=80, objective="maxmin")
    sat = saturation_throughput(layers, tpu, p, n_requests=64)
    assert sat == pytest.approx(p.steady_throughput, rel=SIM_TOL)


@pytest.mark.parametrize("n_parts", [1, 3])
def test_temporal_saturation_matches_amortized_throughput(n_parts):
    layers = sparse_cnn_workload(RESNET18, seed=1)
    hw = FPGAModel()
    p = partition_pipeline(layers, hw, 4096.0, n_parts=n_parts, batch=64,
                           reconfig_cycles=1e6, dse_iters=100)
    sat = saturation_throughput(layers, hw, p, reconfig_cycles=1e6)
    assert sat == pytest.approx(p.throughput, rel=SIM_TOL)


def test_temporal_mode_forced_on_multichip_uses_ici_switches():
    layers = sparse_cnn_workload(RESNET18, seed=2)
    tpu = TPUModel(chips=3)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=64, dse_iters=80, objective="sum")
    sat = saturation_throughput(layers, tpu, p, mode="temporal")
    assert sat == pytest.approx(p.throughput, rel=SIM_TOL)


# --------------------------------------------------------------------- #
# Switch stalls, backpressure, latency invariants
# --------------------------------------------------------------------- #
def test_single_resident_partition_incurs_zero_switch_stalls():
    """Regression: the P - 1 switch accounting must have no P = 1 term."""
    layers = sparse_cnn_workload(RESNET18, seed=1)[:8]
    hw = FPGAModel()
    p1 = partition_pipeline(layers, hw, 256.0, n_parts=1, batch=32,
                            reconfig_cycles=1e12, dse_iters=60)
    rep = simulate_partition(layers, hw, p1,
                             poisson_trace(100, 1e-6, sizes=32, seed=0),
                             reconfig_cycles=1e12)
    assert p1.cuts == []
    assert rep.switch_stalls == 0
    assert rep.switch_stall_cycles == 0.0


def test_temporal_switch_stalls_are_p_minus_1_per_request():
    layers = sparse_cnn_workload(RESNET18, seed=1)
    hw = FPGAModel()
    p = partition_pipeline(layers, hw, 4096.0, n_parts=3, batch=32,
                           reconfig_cycles=1e6, dse_iters=80)
    assert len(p.cuts) >= 1
    n = 40
    rep = simulate_partition(layers, hw, p, backlogged_trace(n, 32),
                             reconfig_cycles=1e6)
    assert rep.switch_stalls == len(p.cuts) * n
    assert rep.switch_stall_cycles == pytest.approx(
        len(p.cuts) * 1e6 * n, rel=1e-12)


def test_backpressure_respects_queue_depth():
    layers = sparse_cnn_workload(RESNET18, seed=4)
    tpu = TPUModel(chips=4)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=32, dse_iters=80)
    for q_depth in (1, 4):
        rep = simulate_partition(layers, tpu, p,
                                 backlogged_trace(60, 32), q_depth=q_depth)
        assert rep.mode == "spatial"
        assert max(rep.queue_max[1:]) <= q_depth    # internal queues only
        assert rep.queue_max[0] > q_depth           # admission backlog
    with pytest.raises(ValueError, match="q_depth"):
        simulate_partition(layers, tpu, p, backlogged_trace(4, 32),
                           q_depth=0)


def test_latency_bounded_below_by_no_wait_service_path():
    layers = sparse_cnn_workload(MOBILENETV3S, seed=5)
    tpu = TPUModel(chips=3)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=16, dse_iters=80)
    tr = poisson_trace(200, request_rate(p.steady_throughput, 0.4, 16),
                       sizes=16, seed=0)
    rep = simulate_partition(layers, tpu, p, tr)
    base = sum(b(16) for b in
               [lambda s, r=r: s / r for r in p.part_throughput])
    assert rep.latency.min() >= base * (1 - 1e-12)
    assert rep.completed == len(tr)
    assert np.all(rep.completions > rep.arrivals)


def test_latency_percentiles_monotone_in_load():
    layers = sparse_cnn_workload(RESNET18, seed=6)
    tpu = TPUModel(chips=2)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=16, dse_iters=80)
    rate = request_rate(p.steady_throughput, 0.3, 16)
    tr = mmpp_trace(400, 0.6 * rate, 3 * rate, dwell_base=4 / rate,
                    dwell_burst=1 / rate, sizes=16, seed=0)
    lo = simulate_partition(layers, tpu, p, tr)
    hi = simulate_partition(layers, tpu, p, tr.scaled(2.5))
    assert lo.p50 <= lo.p95 <= lo.p99
    assert hi.p99 >= lo.p99
    assert hi.queue_mean[0] >= lo.queue_mean[0]


def test_report_utilization_and_throughput_sanity():
    layers = sparse_cnn_workload(RESNET18, seed=7)
    tpu = TPUModel(chips=3)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=16, dse_iters=80)
    rep = simulate_partition(layers, tpu, p, backlogged_trace(64, 16))
    assert np.all(rep.utilization <= 1.0 + 1e-12)
    # the bottleneck node saturates under a backlogged trace
    assert rep.utilization.max() > 0.95
    assert rep.achieved_throughput <= p.steady_throughput * (1 + 1e-9)
    assert rep.windowed_throughput() >= rep.achieved_throughput
    # degenerate traces have no measurement window: fall back to the
    # whole-horizon rate instead of inf / crashing
    one_req = simulate_partition(layers, tpu, p, backlogged_trace(1, 16))
    assert one_req.windowed_throughput() == one_req.achieved_throughput
    assert np.isfinite(one_req.windowed_throughput())


# --------------------------------------------------------------------- #
# Calendar-queue engine: bit-identity with the heap engine + conservation
# --------------------------------------------------------------------- #
_CHAIN_FIELDS = ("completions", "busy", "blocked", "idle",
                 "queue_mean", "queue_max", "down")


def _fuzz_trace(kind: str, n: int, seed: int):
    if kind == "poisson":
        return poisson_trace(n, 2e-6, sizes=[4, 8, 16], seed=seed)
    if kind == "backlogged":
        return backlogged_trace(n, 8)
    if kind == "mmpp":
        return mmpp_trace(n, 1e-6, 5e-6, dwell_base=1e7, dwell_burst=2e6,
                          sizes=8, seed=seed)
    return diurnal_trace(n, 1e-6, 4e-6, 1e8, sizes=8, seed=seed)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       kind=st.sampled_from(["poisson", "backlogged", "mmpp", "diurnal"]),
       m=st.integers(1, 4), q_depth=st.integers(1, 5))
def test_property_calendar_engine_bit_identical_to_heap(seed, kind, m,
                                                        q_depth):
    """The refactor's contract: the calendar-queue engine (including the
    M=1 busy-period fast path) reproduces the heap engine's ``SimReport``
    arrays **bitwise** — same float-add order, same FIFO tie resolution —
    on randomized chains, queue depths, and traffic shapes."""
    from repro.sim.engine import _simulate_chain
    rng = np.random.default_rng(seed)
    tr = _fuzz_trace(kind, 150, seed)
    service = [lambda sz, f=float(rng.uniform(5e4, 5e5)): sz * f + 1e3
               for _ in range(m)]
    caps = [len(tr) + 1] + [q_depth] * (m - 1)
    a = _simulate_chain(tr.arrivals, tr.sizes, service, caps, engine="heap")
    b = _simulate_chain(tr.arrivals, tr.sizes, service, caps,
                        engine="calendar")
    for name, x, y in zip(_CHAIN_FIELDS, a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), name


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       kind=st.sampled_from(["poisson", "backlogged", "mmpp", "diurnal"]),
       m=st.integers(1, 4),
       engine=st.sampled_from(["heap", "calendar"]))
def test_property_time_conservation_busy_blocked_idle(seed, kind, m,
                                                      engine):
    """Regression for the end-of-simulation flush: every node's open
    blocked/idle interval must be closed at the horizon, so per-node
    ``busy + blocked + idle == horizon`` exactly (up to float summation).
    Before the fix the open blocked interval of a backpressured node was
    silently dropped and the books did not balance."""
    from repro.sim.engine import _simulate_chain
    rng = np.random.default_rng(seed)
    tr = _fuzz_trace(kind, 120, seed)
    service = [lambda sz, f=float(rng.uniform(5e4, 5e5)): sz * f + 1e3
               for _ in range(m)]
    caps = [len(tr) + 1] + [1] * (m - 1)      # depth-1: maximal blocking
    completions, busy, blocked, idle, _, _, _ = _simulate_chain(
        tr.arrivals, tr.sizes, service, caps, engine=engine)
    horizon = float(np.max(completions))
    total = np.asarray(busy) + np.asarray(blocked) + np.asarray(idle)
    assert np.allclose(total, horizon, rtol=1e-9, atol=1e-6)
    if m > 1:
        assert np.asarray(blocked)[:-1].sum() >= 0.0
        assert np.asarray(idle).min() >= 0.0


def test_simulate_partition_engine_parameter_and_idle_field():
    """``simulate_partition(engine=...)`` dispatches both engines and the
    report's new ``idle`` column completes the per-node time budget."""
    layers = sparse_cnn_workload(RESNET18, seed=9)
    tpu = TPUModel(chips=3)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=16, dse_iters=80)
    tr = poisson_trace(120, request_rate(p.steady_throughput, 0.5, 16),
                       sizes=16, seed=0)
    a = simulate_partition(layers, tpu, p, tr, engine="heap")
    b = simulate_partition(layers, tpu, p, tr, engine="calendar")
    assert np.array_equal(a.completions, b.completions)
    assert np.array_equal(a.idle, b.idle)
    assert np.allclose(a.busy + a.blocked + a.idle, a.horizon, rtol=1e-9)
    with pytest.raises(ValueError, match="engine"):
        simulate_partition(layers, tpu, p, tr, engine="quantum")


# --------------------------------------------------------------------- #
# SLO-aware partition search
# --------------------------------------------------------------------- #
def _slo_setup(seed=0):
    layers = sparse_cnn_workload(RESNET18, seed=seed)
    tpu = TPUModel(chips=4)
    mm = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                            batch=16, dse_iters=80, objective="maxmin")
    rate = request_rate(mm.steady_throughput, 0.4, 16)
    tr = mmpp_trace(250, 0.6 * rate, 3 * rate, dwell_base=4 / rate,
                    dwell_burst=1 / rate, sizes=16, seed=seed)
    return layers, tpu, mm, tr


def test_slo_objective_reduces_to_maxmin_when_slack():
    layers, tpu, mm, tr = _slo_setup()
    rep = simulate_partition(layers, tpu, mm, tr)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=16, dse_iters=80, objective="slo",
                           slo=SLO(target=rep.p99 * 100.0), trace=tr)
    assert r.objective == "slo"
    assert r.cuts == mm.cuts
    assert r.sim_report is not None
    assert latency_percentile(r.sim_report, 99.0) <= rep.p99 * 100.0


def test_slo_objective_returns_least_violating_when_impossible():
    layers, tpu, mm, tr = _slo_setup(seed=1)
    r = slo_partition_search(layers, tpu, tpu.chip_budget,
                             slo=SLO(target=1.0), trace=tr, n_parts=4,
                             batch=16, dse_iters=80)
    assert r.objective == "slo"
    assert r.sim_report is not None
    # no candidate can meet 1 cycle; the winner minimizes the tail
    assert latency_percentile(r.sim_report, 99.0) > 1.0


def test_slo_objective_validation():
    layers, tpu, mm, tr = _slo_setup(seed=2)
    with pytest.raises(ValueError, match="trace"):
        partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           objective="slo", slo=SLO(target=1e9))
    with pytest.raises(ValueError, match="slo"):
        partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           objective="slo", trace=tr)
    with pytest.raises(ValueError, match="slo"):
        partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           objective="maxmin", trace=tr, dse_iters=60)
    # a bare float is accepted as a p99 target
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=16, dse_iters=80, objective="slo",
                           slo=1e30, trace=tr)
    assert r.objective == "slo"


# --------------------------------------------------------------------- #
# Search + serving integration
# --------------------------------------------------------------------- #
def test_hass_search_scores_the_lat_term():
    """Lambdas.lat wires a reported ``lat`` metric into Eq. 6 (and the
    default 0.0 leaves scores untouched)."""
    m0 = {"acc": 0.8, "spa": 0.5, "thr": 10.0, "thr_norm": 0.4,
          "dsp": 0.6, "lat": 2.0}

    def fake(x):
        return dict(m0)

    lam = Lambdas(lat=0.25)
    r = hass_search(fake, 3, iters=2, lambdas=lam, seed=0)
    want = m0["acc"] + lam.spa * m0["spa"]      # record()'s own fold order
    want += lam.thr * m0["thr_norm"] - lam.dsp * m0["dsp"]
    assert r.best_score == want - 0.25 * m0["lat"]
    r0 = hass_search(fake, 3, iters=2, lambdas=Lambdas(), seed=0)
    assert r0.best_score == want


def test_sim_latency_evaluator_batch_path_matches_serial():
    """The wrapper must route batches through the base evaluator's own
    batch path (review finding: a per-proposal loop would silently drop
    the vmapped CNN fast path) and still report identical metrics on an
    analytic base."""
    from repro.configs import get_config
    from repro.core.hass import LMEvaluator
    from repro.core.perf_model import TPUModel
    from repro.sim import SimLatencyEvaluator

    tpu = TPUModel(chips=2)
    base = LMEvaluator(get_config("qwen3-0.6b"), tpu, tpu.chip_budget,
                       dse_iters=80)
    ev = SimLatencyEvaluator(base, tpu, tpu.chip_budget,
                             trace=poisson_trace(60, 1e-6, sizes=16,
                                                 seed=0),
                             slo=SLO(target=1e8), n_parts=2, batch=16,
                             dse_iters=80)
    rng = np.random.default_rng(0)
    xs = [rng.uniform(0.0, 0.8, ev.n_search) for _ in range(3)]
    batched = ev.evaluate_batch(xs)
    assert batched == [ev(x) for x in xs]
    assert all("lat" in m and "lat_cycles" in m for m in batched)
    # the lambdas sync hass_search performs must reach the wrapped base
    from repro.core.hass import Lambdas
    ev.lambdas = Lambdas(lat=0.7)
    assert base.lambdas.lat == 0.7


def test_requests_from_trace_materializes_sizes():
    tr = poisson_trace(20, 1e-5, sizes=((4, 16), (0.5, 0.5)), seed=3)
    reqs = requests_from_trace(tr, vocab_size=100, prompt_len=5, seed=0)
    assert [r.max_new for r in reqs] == [int(s) for s in tr.sizes]
    assert all(len(r.prompt) == 5 for r in reqs)
    assert all(0 <= t < 100 for r in reqs for t in r.prompt)
    again = requests_from_trace(tr, vocab_size=100, prompt_len=5, seed=0)
    assert all(np.array_equal(a.prompt, b.prompt)
               for a, b in zip(reqs, again))
