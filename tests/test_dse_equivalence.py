"""Vectorized DSE engine == scalar reference, exactly.

The vectorized ``incremental_dse`` / ``rate_balance`` must reproduce the
reference implementations bit for bit (designs, throughput, resource, trace)
across both hardware backends and randomized layer stacks — the contract that
makes the 10x+ speedup (benchmarks/dse_bench.py) a pure refactor.
"""
import numpy as np
import pytest

from repro.configs.paper_cnns import MOBILENETV3S, RESNET18
from repro.core.dse import (incremental_dse, incremental_dse_ref,
                            rate_balance, rate_balance_ref)
from repro.core.perf_model import (DesignPoint, FPGAModel, LayerCost,
                                   TPUModel, cnn_layer_costs)

HW = [(FPGAModel(), 12288.0), (TPUModel(), TPUModel().budget)]


def _random_stack(rng, L):
    return [LayerCost(f"l{i}", macs=int(rng.integers(0, 10 ** 7)),
                      m_dot=int(rng.integers(1, 4096)),
                      weight_count=1, act_in=1, act_out=1,
                      s_w=float(rng.uniform(0, 1.0)),
                      s_a=float(rng.uniform(0, 0.9)),
                      s_w_tile=float(rng.uniform(0, 0.5)),
                      prunable=bool(rng.integers(2)))
            for i in range(L)]


def _assert_same(a, b):
    assert a.designs == b.designs
    assert a.throughput == b.throughput
    assert a.resource == b.resource
    assert a.trace == b.trace


@pytest.mark.parametrize("hw,budget", HW, ids=["fpga", "tpu"])
def test_incremental_dse_matches_ref_on_paper_cnn(hw, budget):
    rng = np.random.default_rng(0)
    layers = cnn_layer_costs(RESNET18)
    for l in layers:
        l.s_w = float(rng.uniform(0.1, 0.8))
        l.s_a = float(rng.uniform(0.1, 0.6))
        l.s_w_tile = float(rng.uniform(0.0, 0.4))
    _assert_same(incremental_dse(layers, hw, budget, max_iters=500),
                 incremental_dse_ref(layers, hw, budget, max_iters=500))


@pytest.mark.parametrize("hw,budget", HW, ids=["fpga", "tpu"])
def test_incremental_dse_matches_ref_randomized(hw, budget):
    rng = np.random.default_rng(42)
    for trial in range(12):
        layers = _random_stack(rng, int(rng.integers(1, 24)))
        b = float(rng.integers(1, int(budget)))
        _assert_same(incremental_dse(layers, hw, b, max_iters=200),
                     incremental_dse_ref(layers, hw, b, max_iters=200))


def test_incremental_dse_budget_sweep_identical_frontier():
    """The (resource, throughput) frontier the DSE traces out matches the
    reference at every budget, so downstream search scores are unchanged."""
    layers = cnn_layer_costs(MOBILENETV3S)[:12]
    hw = FPGAModel()
    for budget in (16, 64, 256, 1024, 4096):
        _assert_same(incremental_dse(layers, hw, budget, max_iters=400),
                     incremental_dse_ref(layers, hw, budget, max_iters=400))


def test_rate_balance_matches_ref_randomized():
    rng = np.random.default_rng(7)
    hw = FPGAModel()
    for trial in range(20):
        L = int(rng.integers(1, 16))
        layers = _random_stack(rng, L)
        designs = [DesignPoint(int(2 ** rng.integers(0, 10)),
                               int(2 ** rng.integers(0, 10)))
                   for _ in range(L)]
        protect = set(int(i) for i in
                      rng.choice(L, size=int(rng.integers(0, L)),
                                 replace=False)) if L > 1 else set()
        for strict in (False, True):
            assert rate_balance(layers, designs, hw, protect=protect,
                                strict=strict) == \
                rate_balance_ref(layers, designs, hw, protect=protect,
                                 strict=strict)


def test_throughput_vec_matches_scalar():
    rng = np.random.default_rng(3)
    for hw, _ in HW:
        layers = _random_stack(rng, 16)
        lv = hw.layer_vectors(layers)
        spe = 2 ** rng.integers(0, 10, size=16)
        n = 2 ** rng.integers(0, 8, size=16)
        vec_thr = hw.throughput_vec(lv, spe, n)
        vec_res = hw.resource_vec(lv, spe, n)
        for i, l in enumerate(layers):
            d = DesignPoint(int(spe[i]), int(n[i]))
            assert vec_thr[i] == hw.layer_throughput(l, d)
            assert vec_res[i] == hw.layer_resource(l, d)
