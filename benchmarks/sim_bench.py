"""Deployment-simulator gates (DESIGN.md §13).

Three sections, saved to ``experiments/sim_bench.json``:

  * ``agreement`` — the sim-vs-analytic contract: across randomized sparse
    stacks (CNN + LM), chip counts, DP objectives, heterogeneous budgets,
    and the temporal schedule, the simulator's backlogged saturation rate
    must match the analytic model (``steady_throughput`` spatial,
    amortized ``throughput`` temporal) within ``SIM_TOL``. Hard gate.
  * ``slo`` — the rate/latency trade-off scenario: a stack whose ICI hops
    are moderately expensive (priced just below the stage rates, so the
    max-min DP still takes them for 4x saturation) serving a bursty MMPP
    trace at mid utilization. ``objective="slo"`` must pick a partition
    with strictly lower simulated p99 than the max-min pick — the
    acceptance gate: the SLO binds and the search walks away from the
    rate-optimal cuts.
  * ``latency`` — report-only: tail latencies of a searched sparse LM
    stack across traffic shapes (poisson / mmpp / diurnal) and offered
    loads on a 4-chip slice.

    PYTHONPATH=src:. python benchmarks/sim_bench.py [--smoke]
"""
import argparse

import numpy as np

from benchmarks.common import emit, save_json
from benchmarks.dse_bench import _sparse_workload as _sparse_cnn
from repro.configs import get_config, reduce_config
from repro.configs.paper_cnns import MOBILENETV3S, RESNET18
from repro.core.dse import partition_pipeline
from repro.core.perf_model import (ACT_BYTES, ICI_BW, ICI_LINKS, FPGAModel,
                                   LayerCost, TPUModel, lm_block_bounds,
                                   lm_layer_costs, thin_cut_points)
from repro.sim import (SIM_TOL, SLO, diurnal_trace, mmpp_trace,
                       poisson_trace, request_rate, saturation_throughput,
                       simulate_partition)
from repro.sim.slo import latency_percentile


def _sparse_lm(arch, seed, reduced=True):
    cfg = get_config(arch)
    layers = lm_layer_costs(reduce_config(cfg) if reduced else cfg,
                            seq_len=128)
    rng = np.random.default_rng(seed)
    for l in layers:
        if l.prunable:
            l.s_w = l.s_w_tile = float(rng.uniform(0.0, 0.8))
    return layers


def bench_agreement(smoke: bool):
    """Fuzzed sim-vs-analytic saturation agreement (hard gate: SIM_TOL)."""
    cases = []
    seeds = (0, 1) if smoke else (0, 1, 2, 3)
    for seed in seeds:
        cases.append(("cnn", _sparse_cnn(MOBILENETV3S, seed),
                      None, 2 + seed % 3, "maxmin"))
        cases.append(("lm", _sparse_lm("qwen3-0.6b", seed), "blocks",
                      2 + (seed + 1) % 3, "sum"))
    rows = []
    worst = 0.0
    for tag, layers, cuts, chips, objective in cases:
        tpu = TPUModel(chips=chips)
        cut_points = lm_block_bounds(layers) if cuts == "blocks" else None
        p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=chips,
                               batch=32, dse_iters=100, objective=objective,
                               cut_points=cut_points)
        sat = saturation_throughput(layers, tpu, p, n_requests=64)
        err = abs(sat - p.steady_throughput) / p.steady_throughput
        worst = max(worst, err)
        rows.append({"workload": tag, "chips": chips,
                     "objective": objective, "cuts": p.cuts,
                     "steady_analytic": p.steady_throughput,
                     "steady_sim": sat, "rel_err": err})
    # heterogeneous slice
    layers = _sparse_cnn(RESNET18, 7)
    het = TPUModel(chips=3, chip_lanes=(512.0, 256.0, 384.0))
    p = partition_pipeline(layers, het, het.chip_budget, n_parts=3,
                           batch=32, dse_iters=100, objective="maxmin")
    sat = saturation_throughput(layers, het, p, n_requests=64)
    err = abs(sat - p.steady_throughput) / p.steady_throughput
    worst = max(worst, err)
    rows.append({"workload": "cnn_hetero", "chips": 3, "objective": "maxmin",
                 "chip_budgets": p.chip_budgets, "cuts": p.cuts,
                 "steady_analytic": p.steady_throughput, "steady_sim": sat,
                 "rel_err": err})
    # temporal schedule: amortized rate at size == batch
    layers = _sparse_cnn(RESNET18, 8)
    fpga = FPGAModel()
    p = partition_pipeline(layers, fpga, 4096.0, n_parts=3, batch=64,
                           reconfig_cycles=1e6, dse_iters=100)
    sat = saturation_throughput(layers, fpga, p, reconfig_cycles=1e6)
    err = abs(sat - p.throughput) / p.throughput
    worst = max(worst, err)
    rows.append({"workload": "cnn_temporal", "chips": 1, "objective": "sum",
                 "cuts": p.cuts, "amortized_analytic": p.throughput,
                 "amortized_sim": sat, "rel_err": err})
    print(f"  agreement: {len(rows)} randomized partitions, worst rel err "
          f"{worst:.2e} (tol {SIM_TOL:.0e})")
    assert worst <= SIM_TOL, \
        f"sim-vs-analytic saturation diverged: {worst:.3e} > {SIM_TOL:.0e}"
    return rows, worst


def _uniform_stack(L: int, width: int, act: float):
    """L identical dense matmul stages with controllable boundary width —
    the knob that prices the ICI hops relative to the stage rates."""
    return [LayerCost(name=f"l{i}", macs=width * width, m_dot=width,
                      weight_count=width * width, act_in=act, act_out=act,
                      kind="linear", prunable=False) for i in range(L)]


def bench_slo(smoke: bool, chips: int = 4, req_tokens: int = 32,
              hop_alpha: float = 0.98, util: float = 0.2, seed: int = 0):
    """The acceptance scenario: a real rate/latency trade-off. Max-min
    takes every hop (3 of them at ~one stage-service each) for 4x
    saturation; the 2-partition max-min pick pays ONE hop for 2x. Under a
    mildly bursty trace the 2-chip pick's simulated tail sits strictly
    below the 4-chip pick's — two hops of pure added latency outweigh the
    4-chip pick's smaller queueing — while the 1-chip deployment's burst
    queueing dominates ITS tail. An SLO strictly between the two tails
    therefore binds: the rate-optimal pick is infeasible, the search walks
    to the 2-chip cuts, and neither extreme of the trade-off wins. The
    whole scenario is seeded and the simulator deterministic, so the
    gated inequality (slo p99 < max-min p99) is exact, not statistical."""
    tpu = TPUModel(chips=chips)
    # pass 1: measure the per-stage rate with negligible hop cost (stage
    # rates depend only on the workloads, not the boundary widths)
    probe = _uniform_stack(2 * chips, 1024, act=1.0)
    mm0 = partition_pipeline(probe, tpu, tpu.chip_budget, n_parts=chips,
                             batch=req_tokens, dse_iters=200,
                             objective="maxmin")
    r_stage = min(mm0.part_throughput)
    # pass 2: widen the boundaries so one hop costs hop_alpha stage-service
    # times per sample — hop rate r_stage/hop_alpha still exceeds every
    # stage rate, so max-min keeps all chips-1 cuts and its 4x rate
    per_elem = ACT_BYTES / (ICI_BW * ICI_LINKS) * tpu.freq
    act = hop_alpha / r_stage / per_elem
    layers = _uniform_stack(2 * chips, 1024, act=act)
    mm = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=chips,
                            batch=req_tokens, dse_iters=200,
                            objective="maxmin")
    one = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=1,
                             batch=req_tokens, dse_iters=200,
                             objective="sum")
    two = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                             batch=req_tokens, dse_iters=200,
                             objective="maxmin")
    n_req = 600 if smoke else 1500
    rate = request_rate(one.steady_throughput, util, req_tokens)
    trace = mmpp_trace(n_req, 0.8 * rate, 1.8 * rate,
                       dwell_base=8.0 / rate, dwell_burst=2.0 / rate,
                       sizes=req_tokens, seed=seed)
    rep_mm = simulate_partition(layers, tpu, mm, trace)
    rep_two = simulate_partition(layers, tpu, two, trace)
    # the structural fact the scenario demonstrates; the SLO target sits
    # strictly between the two tails so it must bind away from max-min
    assert rep_two.p99 < rep_mm.p99, \
        "scenario broken: the 2-chip tail no longer undercuts max-min's"
    slo = SLO(target=0.6 * rep_two.p99 + 0.4 * rep_mm.p99, quantile=99.0)
    sl = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=chips,
                            batch=req_tokens, dse_iters=200,
                            objective="slo", slo=slo, trace=trace)
    p99_slo = latency_percentile(sl.sim_report, 99.0)
    row = {"chips": chips, "hop_alpha": hop_alpha, "util": util,
           "trace": {"kind": trace.kind, "requests": len(trace),
                     "req_tokens": req_tokens},
           "slo_target": slo.target,
           "maxmin": {"cuts": mm.cuts, "steady": mm.steady_throughput,
                      "p99": rep_mm.p99, "p50": rep_mm.p50},
           "slo": {"cuts": sl.cuts, "steady": sl.steady_throughput,
                   "p99": p99_slo,
                   "p50": sl.sim_report.p50}}
    print(f"  slo: maxmin cuts={mm.cuts} steady={mm.steady_throughput:.2e} "
          f"p99={rep_mm.p99:.3e} cy | slo cuts={sl.cuts} "
          f"steady={sl.steady_throughput:.2e} p99={p99_slo:.3e} cy "
          f"(target {slo.target:.3e})")
    assert len(mm.cuts) == chips - 1, \
        "scenario broken: max-min no longer takes every hop"
    assert p99_slo < rep_mm.p99, \
        "SLO pick must beat the max-min pick on simulated p99"
    assert p99_slo <= slo.target, \
        "SLO pick must meet the (feasible-by-construction) target"
    assert sl.cuts != mm.cuts, "the SLO must bind away from the rate pick"
    return row


def bench_latency(smoke: bool, seed: int = 0):
    """Report-only: tail latency of a sparse LM deployment across traffic
    shapes and offered loads."""
    layers = _sparse_lm("qwen3-0.6b", seed, reduced=False)
    tpu = TPUModel(chips=4)
    cuts = thin_cut_points(lm_block_bounds(layers), 10)
    p = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=32, dse_iters=200, cut_points=cuts,
                           objective="maxmin")
    n_req = 300 if smoke else 1000
    utils = (0.3, 0.7) if smoke else (0.3, 0.6, 0.85)
    rows = []
    for util in utils:
        rate = request_rate(p.steady_throughput, util, 32)
        traces = {
            "poisson": poisson_trace(n_req, rate, sizes=32, seed=seed),
            "mmpp": mmpp_trace(n_req, 0.6 * rate, 3.0 * rate,
                               dwell_base=4.0 / rate,
                               dwell_burst=1.0 / rate, sizes=32, seed=seed),
            "diurnal": diurnal_trace(n_req, 0.5 * rate, 1.8 * rate,
                                     period=50.0 / rate, sizes=32,
                                     seed=seed),
        }
        for kind, tr in traces.items():
            rep = simulate_partition(layers, tpu, p, tr)
            rows.append({"trace": kind, "util": util,
                         "p50": rep.p50, "p95": rep.p95, "p99": rep.p99,
                         "achieved": rep.achieved_throughput,
                         "max_stage_util": float(rep.utilization.max()),
                         "backlog_mean": float(rep.queue_mean[0])})
            print(f"  latency qwen3 4-chip {kind:8s} util={util:.2f}: "
                  f"p50={rep.p50:.3e} p95={rep.p95:.3e} "
                  f"p99={rep.p99:.3e} cy")
    return {"cuts": p.cuts, "steady": p.steady_throughput, "rows": rows}


def run(smoke: bool = False):
    print("deployment simulator: sim-vs-analytic agreement")
    agree_rows, worst = bench_agreement(smoke)
    print("SLO-aware partition search (bursty trace)")
    slo_row = bench_slo(smoke)
    print("latency percentiles across traffic shapes")
    lat_rows = bench_latency(smoke)
    payload = {"smoke": smoke, "sim_tol": SIM_TOL,
               "agreement": agree_rows, "worst_agreement_err": worst,
               "slo": slo_row, "latency": lat_rows}
    save_json("sim_bench.json", payload)
    emit("sim_bench.agreement", 0.0,
         f"worst_rel_err={worst:.2e} (tol {SIM_TOL:.0e}) over "
         f"{len(agree_rows)} randomized partitions")
    emit("sim_bench.slo", 0.0,
         f"slo_p99={slo_row['slo']['p99']:.3e} < "
         f"maxmin_p99={slo_row['maxmin']['p99']:.3e} cycles")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced seeds/trace lengths for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
