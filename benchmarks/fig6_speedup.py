"""Fig. 6 analogue: dense -> sparse modeled speedup per model, at matched
resource budgets (the benefit of exploiting both weight and activation
sparsity in the dataflow pipeline)."""
import dataclasses

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.dse import incremental_dse
from repro.core.perf_model import FPGAModel, cnn_layer_costs

BUDGETS = {"resnet18": 12234, "resnet50": 7434, "mobilenetv2": 5261,
           "mobilenetv3s": 1796, "mobilenetv3l": 4324}


def run(s_w: float = 0.6, s_a: float = 0.4, seed: int = 0):
    hw = FPGAModel()
    out = {}
    for cfg in PAPER_CNNS:
        layers = cnn_layer_costs(cfg)
        sparse = [dataclasses.replace(l, s_w=s_w if l.prunable else 0.0,
                                      s_a=s_a if l.prunable else 0.0)
                  for l in layers]
        budget = BUDGETS[cfg.name]

        def both():
            d = incremental_dse(layers, hw, budget, max_iters=2500)
            s = incremental_dse(sparse, hw, budget, max_iters=2500)
            return d, s
        (dense, spr), us = timed(both)
        speedup = spr.throughput / max(dense.throughput, 1e-18)
        out[cfg.name] = {
            "dense_images_s": dense.throughput * hw.freq,
            "sparse_images_s": spr.throughput * hw.freq,
            "speedup": speedup,
        }
        emit(f"fig6.{cfg.name}", us, f"speedup={speedup:.2f}x "
             f"dense={dense.throughput * hw.freq:.0f} "
             f"sparse={spr.throughput * hw.freq:.0f} img/s")
    save_json("fig6.json", out)
    return out


if __name__ == "__main__":
    run()
