"""Table II analogue: dense vs HASS-sparse designs for the paper's models.

For each CNN (ResNet-18/50, MobileNetV2, MobileNetV3-S/L):
  * dense DSE -> modeled throughput + resource (the 'Dense' columns),
  * short HASS search -> sparse design (the 'Ours' columns),
  * report throughput (samples/s), resource units, efficiency
    (samples/cycle/DSP x 1e9 — the paper's images/cycle/DSP) and the
    sparse/dense efficiency ratio (paper: 1.3-4.2x).
Accuracy proxies come from reduced-resolution forwards; C_l and the DSE use
the full 224x224 layer costs (analytic — no forward needed).
"""
import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed, trained_cnn
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core.dse import incremental_dse
from repro.core.hass import CNNEvaluator, hass_search
from repro.core.perf_model import FPGAModel, cnn_layer_costs

BUDGETS = {"resnet18": 12234, "resnet50": 7434, "mobilenetv2": 5261,
           "mobilenetv3s": 1796, "mobilenetv3l": 4324}     # Table II (Ours)


def run(iters: int = 12, img_res: int = 64, seed: int = 0):
    hw = FPGAModel()
    rows = {}
    for cfg in PAPER_CNNS:
        small = dataclasses.replace(cfg, img_res=img_res)
        params = trained_cnn(small, steps=20)
        images = jax.random.normal(jax.random.PRNGKey(seed),
                                   (8, img_res, img_res, 3))
        budget = BUDGETS[cfg.name]
        ev = CNNEvaluator(small, params, images, hw, budget=budget,
                          dse_iters=800, cost_cfg=cfg)

        dense = incremental_dse(ev.layers, hw, budget, max_iters=2500)
        dense_thr = dense.throughput * hw.freq
        dense_eff = dense.throughput / max(dense.resource, 1e-9) * 1e9

        def search():
            return hass_search(ev, len(ev.prunable), iters=iters,
                               hardware_aware=True, seed=seed)
        res, us = timed(search)
        m = res.best_metrics
        eff = m["thr"] / hw.freq / max(m["dsp"] * budget, 1e-9) * 1e9
        rows[cfg.name] = {
            "dense_images_s": dense_thr, "dense_res": dense.resource,
            "dense_eff_e9": dense_eff,
            "sparse_images_s": m["thr"], "sparse_res": m["dsp"] * budget,
            "sparse_eff_e9": eff, "acc_proxy": m["acc"], "spa": m["spa"],
            "eff_ratio": eff / max(dense_eff, 1e-12),
            "search_s": us / 1e6,
        }
        emit(f"table2.{cfg.name}", us,
             f"eff_ratio={eff / max(dense_eff, 1e-12):.2f}x "
             f"acc={m['acc']:.3f} thr={m['thr']:.0f}img/s")
    save_json("table2.json", rows)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--img-res", type=int, default=64)
    args = ap.parse_args()
    run(iters=args.iters, img_res=args.img_res)
