"""Kernel microbenchmarks (interpret mode on CPU — numbers prove the schedule
shrinks with sparsity, not TPU wall-time; grid-step counts are the structural
metric, matching Eq. 1 at tile granularity)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops
from repro.kernels.block_sparse_matmul import (_SCHEDULE_CACHE,
                                               _build_tile_schedule_ref,
                                               build_tile_schedule)


def bench_schedule(seed: int = 0):
    """Schedule build (vectorized vs per-column-loop reference) and reuse
    (mask-hash memo hit) — the compile-time arbiter cost per pruned weight."""
    rng = np.random.default_rng(seed)
    for kt, nt, density in ((56, 56, 0.5), (112, 112, 0.25)):
        mask = rng.random((kt, nt)) < density
        c_ref, i_ref = _build_tile_schedule_ref(mask)
        _SCHEDULE_CACHE.clear()
        t0 = time.perf_counter()
        c, i = build_tile_schedule(mask)
        t_cold = time.perf_counter() - t0
        assert np.array_equal(c, c_ref) and np.array_equal(i, i_ref)
        t0 = time.perf_counter()
        reps = 50
        for _ in range(reps):
            build_tile_schedule(mask)
        t_hit = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        _build_tile_schedule_ref(mask)
        t_ref = time.perf_counter() - t0
        emit(f"kernel.schedule.{kt}x{nt}", t_cold * 1e6,
             f"ref={t_ref * 1e6:.0f}us memo_hit={t_hit * 1e6:.1f}us "
             f"(reuse {t_ref / max(t_hit, 1e-9):.0f}x)")
        assert t_hit < t_ref, "schedule memo regressed: hit slower than ref"


def run(seed: int = 0):
    bench_schedule(seed)
    rng = np.random.default_rng(seed)
    M = K = N = 256
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    for tile_density in (1.0, 0.5, 0.25):
        w = rng.normal(size=(K, N)).astype(np.float32)
        Kt, Nt = K // 128, N // 128
        keep = rng.random((Kt, Nt)) < tile_density
        if not keep.any():
            keep[0, 0] = True
        for i in range(Kt):
            for j in range(Nt):
                if not keep[i, j]:
                    w[i * 128:(i + 1) * 128, j * 128:(j + 1) * 128] = 0.0
        sw = ops.SparseWeight(jnp.asarray(w))
        fn = jax.jit(lambda xx: sw.matmul(xx, interpret=True))
        fn(x).block_until_ready()
        _, us = timed(lambda: fn(x).block_until_ready(), repeat=3)
        steps = int(np.asarray(sw.counts).sum()) * (M // 128)
        dense_steps = Kt * Nt * (M // 128)
        emit(f"kernel.bsmm.density{tile_density}", us,
             f"grid_steps={steps}/{dense_steps} "
             f"(skip={(1 - steps / dense_steps):.0%})")

    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    fn2 = jax.jit(lambda aa: ops.act_clip(aa, 0.5, interpret=True)[0])
    fn2(a).block_until_ready()
    _, us = timed(lambda: fn2(a).block_until_ready(), repeat=3)
    emit("kernel.act_clip.512x512", us, "fused clip+count, one VMEM pass")


if __name__ == "__main__":
    run()
