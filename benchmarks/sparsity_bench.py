"""Sparsity-pattern design-axis gate (DESIGN.md §16).

Four sections, saved to ``experiments/sparsity_bench.json``:

  * ``kernel_costs`` — the seeded per-pattern decode microbench
    (``kernels.kernel_costs``), cached to ``experiments/kernel_costs.json``
    (byte-deterministic; re-running must not dirty the checked-in file),
    condensed to per-pattern decode factors c_p >= 1.
  * ``default_identity`` — HARD GATE: ``hass_search`` with the degenerate
    pattern axis (``patterns=("unstructured",)``) replays the pre-pattern
    (``patterns=None``) transcript trial-for-trial bit-identically on a CNN
    (FPGA) and a kind-tied LM (TPU) evaluator, serial AND batched.
  * ``pattern_win`` — HARD GATE: on a TPU CNN stack and a kind-tied TPU LM
    stack, the pattern-aware search (unstructured / N:M / hierarchical /
    activation as tied categorical TPE variables) finds a trial that
    PARETO-DOMINATES the unstructured-only arm: at its own accuracy proxy
    ``a`` (above a per-stack floor), its modeled hardware score
    (λthr·thr_norm − λdsp·dsp) strictly beats EVERY unstructured trial with
    accuracy >= ``a``. Both arms are anchored with a dense ``x0`` trial, so
    the comparison always includes the honest "don't prune at all" point
    and can never go vacuous. The mechanism: the MXU only skips whole
    tiles, so unstructured pruning pays accuracy linearly in the tile
    fraction, while N:M / hierarchical keep the largest magnitudes per
    group AND count element-granular effective sparsity in Eq. 1. The win
    is on MODELED costs (the paper's dataflow assumption: element-granular
    skipping is native); the CPU-measured decode factors are far more
    punitive than real sparse datapaths (~2.2x for N:M gather) and are
    gated separately below.
  * ``meas_term`` — HARD GATE: with measured decode factors installed
    (``pattern_costs``), every pattern-arm trial reports the Eq. 6 ``meas``
    term, the recorded score subtracts ``lambdas.meas * meas`` exactly, and
    an all-N:M assignment prices strictly above an all-unstructured one.
  * ``executed`` — HARD GATE: the winning assignment's dominant pattern is
    realized on a real weight, its tile schedule built, and the schedule
    EXECUTED through the ``block_sparse_matmul`` Pallas kernel (interpret
    mode) against the dense jnp reference.

    PYTHONPATH=src:. python benchmarks/sparsity_bench.py [--smoke]
"""
import argparse
import os

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, emit, save_json, trained_cnn
from repro.configs import get_config, reduce_config
from repro.configs.paper_cnns import RESNET18
from repro.core import pruning
from repro.core.hass import CNNEvaluator, Lambdas, LMEvaluator, hass_search
from repro.core.perf_model import FPGAModel, TPUModel
from repro.kernels import kernel_costs

PATTERNS = pruning.PATTERNS
COSTS_PATH = os.path.join(RESULTS_DIR, "kernel_costs.json")


def bench_kernel_costs():
    table = kernel_costs.load_or_measure(COSTS_PATH)
    # determinism: a fresh in-memory measurement reproduces the cached table
    again = kernel_costs.measure(
        kernel_costs.MicrobenchConfig(**{
            k: tuple(v) if isinstance(v, list) else v
            for k, v in table["config"].items() if k != "schema"}))
    assert again == table, "kernel cost microbench is not deterministic"
    factors = table["decode_factors"]
    assert set(factors) == set(PATTERNS)
    assert all(v >= 1.0 for v in factors.values())
    for p in PATTERNS:
        print(f"  decode_factor[{p:13s}] = {factors[p]:.4f} ")
    return {"factors": factors, "dense_mode": table["dense"]["mode"],
            "path": os.path.relpath(COSTS_PATH,
                                    os.path.join(RESULTS_DIR, ".."))}


def _assert_identical(r0, r1, tag):
    assert len(r0.trials) == len(r1.trials), tag
    for t0, t1 in zip(r0.trials, r1.trials):
        assert np.array_equal(t0.x, t1.x), tag
        assert t0.metrics == t1.metrics, tag
        assert t0.score == t1.score, tag
    assert r0.best_score == r1.best_score, tag


def bench_default_identity(cnn_pack, iters):
    rows = []
    cfg, params, images = cnn_pack
    base = CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                        dse_iters=150)
    pat = CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                       dse_iters=150, patterns=("unstructured",))
    kw = dict(iters=iters, s_max=0.9, seed=0)
    _assert_identical(hass_search(base, len(base.prunable), **kw),
                      hass_search(pat, len(pat.prunable), **kw), "cnn/serial")
    rows.append({"stack": "cnn-fpga", "mode": "serial", "iters": iters,
                 "identical": True})
    print(f"  cnn-fpga   serial   {iters} trials bit-identical")

    lm_cfg = get_config("qwen3-0.6b")
    tpu = TPUModel(chips=1)
    for mode, bs in (("serial", None), ("batched", 4)):
        b = LMEvaluator(lm_cfg, tpu, tpu.budget, dse_iters=150)
        p = LMEvaluator(lm_cfg, tpu, tpu.budget, dse_iters=150,
                        patterns=("unstructured",))
        kw = dict(iters=2 * iters, seed=0, include_act=False, batch_size=bs)
        _assert_identical(hass_search(b, b.n_search, **kw),
                          hass_search(p, p.n_search, **kw), f"lm/{mode}")
        rows.append({"stack": "lm-tpu", "mode": mode, "iters": 2 * iters,
                     "identical": True})
        print(f"  lm-tpu     {mode:8s} {2 * iters} trials bit-identical")
    return rows


def _hw_score(m, lam):
    return lam.thr * m["thr_norm"] - lam.dsp * m["dsp"]


def _win_row(stack, r_u, r_p, lam, floor, n_pat):
    """The gate comparison: Pareto dominance at equal-or-better accuracy.
    A pattern trial at accuracy ``a >= floor`` wins if its modeled hw score
    strictly beats EVERY unstructured trial with accuracy >= ``a``. Both
    arms carry a dense ``x0`` anchor (acc == max), so the unstructured
    competitor set is never empty. Reported: the max-gain dominating
    trial."""
    wins = []
    for t in r_p.trials:
        a = t.metrics["acc"]
        if a < floor:
            continue
        hw_u = max(_hw_score(u.metrics, lam) for u in r_u.trials
                   if u.metrics["acc"] >= a)
        hw_p = _hw_score(t.metrics, lam)
        if hw_p > hw_u:
            wins.append((hw_p - hw_u, a, hw_p, hw_u, t))
    assert wins, \
        f"{stack}: no pattern trial with acc >= {floor} strictly beats " \
        f"the unstructured arm's hw score at equal-or-better accuracy"
    gain, acc, hw_p, hw_u, best = max(wins, key=lambda w: w[0])
    # a genuine pattern win, not an unstructured config the other arm's TPE
    # happened to miss: the dominating trial assigns a non-default pattern
    codes = np.clip(best.x[-n_pat:].astype(np.int64), 0, len(PATTERNS) - 1)
    assert (codes != 0).any(), f"{stack}: dominating trial is all-unstructured"
    print(f"  {stack:10s} {len(wins)} dominating trials; best at "
          f"acc={acc:.3f}  hw: unstructured={hw_u:.4f}  pattern={hw_p:.4f}"
          f"  (+{gain:.4f})")
    return {"stack": stack, "acc": acc, "acc_floor": floor,
            "n_dominating": len(wins), "hw_unstructured": hw_u,
            "hw_pattern": hw_p, "gain": gain}, best


def bench_pattern_win(cnn_pack, iters_cnn, iters_lm):
    lam = Lambdas()
    rows, winners = [], {}

    cfg, params, images = cnn_pack
    tpu = TPUModel()
    ev_u = CNNEvaluator(cfg, params, images, tpu, budget=tpu.chip_budget,
                        dse_iters=150)
    ev_p = CNNEvaluator(cfg, params, images, tpu, budget=tpu.chip_budget,
                        dse_iters=150, patterns=PATTERNS)
    L = len(ev_p.prunable)
    kw = dict(iters=iters_cnn, s_max=0.6, seed=0, lambdas=lam, batch_size=8)
    r_u = hass_search(ev_u, L, **kw, x0=np.zeros(2 * L))
    r_p = hass_search(ev_p, L, **kw, x0=np.zeros(3 * L))
    row, best = _win_row("cnn-tpu", r_u, r_p, lam, floor=0.4, n_pat=L)
    rows.append(row)
    winners["cnn"] = (ev_p, best, L)

    lm_cfg = get_config("qwen3-0.6b")
    lm_u = LMEvaluator(lm_cfg, tpu, tpu.chip_budget, dse_iters=150)
    lm_p = LMEvaluator(lm_cfg, tpu, tpu.chip_budget, dse_iters=150,
                       patterns=PATTERNS)
    assert lm_p.tie == "kind" and lm_p.n_pattern_dims == lm_p.n_search
    n = lm_p.n_search
    kw = dict(iters=iters_lm, seed=0, include_act=False, lambdas=lam,
              s_max=0.6)
    r_u = hass_search(lm_u, n, **kw, x0=np.zeros(n))
    r_p = hass_search(lm_p, n, **kw, x0=np.zeros(2 * n))
    row, best = _win_row("lm-tpu", r_u, r_p, lam, floor=0.6, n_pat=n)
    rows.append(row)
    winners["lm"] = (lm_p, best, n)
    return rows, winners


def bench_meas_term(factors):
    """The measured decode factors feed Eq. 6: with ``pattern_costs``
    installed every trial reports ``meas``, the recorded score subtracts
    ``lambdas.meas * meas`` exactly, and pricing is pattern-sensitive."""
    lam = Lambdas(meas=0.1)
    tpu = TPUModel()
    ev = LMEvaluator(get_config("qwen3-0.6b"), tpu, tpu.chip_budget,
                     dse_iters=150, patterns=PATTERNS, pattern_costs=factors)
    n = ev.n_search
    r = hass_search(ev, n, iters=16, seed=0, include_act=False, lambdas=lam)
    for t in r.trials:
        m = t.metrics
        assert "meas" in m
        want = m["acc"] + lam.spa * m["spa"] + lam.thr * m["thr_norm"] \
            - lam.dsp * m["dsp"] - lam.meas * m["meas"]
        assert abs(want - t.score) < 1e-12
    s = np.full(n, 0.5)
    meas_u = ev(np.concatenate([s, np.full(n, 0.5)]))["meas"]
    meas_nm = ev(np.concatenate([s, np.full(n, 1.5)]))["meas"]
    assert meas_nm > meas_u, \
        f"all-N:M must price above all-unstructured ({meas_nm} <= {meas_u})"
    print(f"  meas wired into Eq. 6 over {len(r.trials)} trials; "
          f"all-nm prices {meas_nm:.3f} > all-unstructured {meas_u:.3f}")
    return {"trials": len(r.trials), "meas_unstructured": meas_u,
            "meas_nm": meas_nm, "lambda_meas": lam.meas}


def _dominant_pattern(ev, best, n):
    """(pattern name, sparsity target) of the winner's largest prunable
    weight share among NON-DEFAULT pattern assignments (the win is
    attributable to those — `_win_row` guarantees at least one exists;
    executing the default unstructured schedule would gate nothing new)."""
    codes = np.clip(best.x[-n:].astype(np.int64), 0, len(ev.patterns) - 1)
    s_w = np.clip(best.x[:n], 0.0, 1.0)
    if hasattr(ev, "_group"):                      # LM: kind-tied
        g = np.asarray(ev._group)
        per_layer = codes[g]
        share = {}
        for c in range(1, len(ev.patterns)):
            share[c] = float(ev._wfrac[per_layer == c].sum())
        c_dom = max(share, key=share.get)
        ks = [k for k in range(n) if codes[k] == c_dom]
        s = float(np.mean(s_w[ks])) if ks else float(s_w.mean())
    else:                                          # CNN: per-layer codes
        wc = np.array([l.weight_count for l in ev.prunable], np.float64)
        share = {}
        for c in range(1, len(ev.patterns)):
            share[c] = float(wc[codes == c].sum())
        c_dom = max(share, key=share.get)
        ks = np.flatnonzero(codes == c_dom)
        s = float(np.average(s_w[ks], weights=wc[ks])) if len(ks) \
            else float(s_w.mean())
    return ev.patterns[c_dom], s


def _realize(pattern, w, s):
    """Prune ``w`` with the winning pattern at target ``s`` — the same
    per-pattern rules the evaluators trace (DESIGN.md §16)."""
    import jax.numpy as jnp
    w = jnp.asarray(w, jnp.float32)
    if pattern == "unstructured":
        return pruning.tile_prune(w, s)[0]
    if pattern == "nm":
        return pruning.nm_prune(w, int(pruning.nm_keep_for_sparsity(s)))
    if pattern == "hierarchical":
        r = float(np.clip(s / (2.0 - s), 0.0, 1.0))
        return pruning.hierarchical_prune(
            w, s / 2.0, int(pruning.nm_keep_for_sparsity(r)))[0]
    return w                                       # activation: dense weights


def bench_executed(winners):
    """Run each stack winner's dominant pattern through the real kernel."""
    from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                                   build_tile_schedule,
                                                   tile_mask)
    import jax.numpy as jnp
    rows = []
    rng = np.random.default_rng(0)
    for stack, (ev, best, n) in winners.items():
        pattern, s = _dominant_pattern(ev, best, n)
        w = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
        w2 = _realize(pattern, w, s)
        mask = tile_mask(np.asarray(w2))
        counts, indices = build_tile_schedule(mask)
        x = jnp.asarray(rng.normal(size=(128, 512)), jnp.float32)
        out = block_sparse_matmul(x, w2, jnp.asarray(counts),
                                  jnp.asarray(indices), interpret=True)
        ref = np.asarray(x @ w2)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-3,
                                   rtol=1e-4)
        steps = int(counts.sum())
        full = mask.shape[0] * mask.shape[1]
        row = {"stack": stack, "pattern": pattern, "s": round(s, 4),
               "element_sparsity": round(float(pruning.sparsity_of(w2)), 4),
               "schedule_steps": steps, "dense_steps": full,
               "kernel_ok": True}
        rows.append(row)
        print(f"  {stack:4s} winner pattern={pattern:13s} s={s:.3f}  "
              f"schedule {steps}/{full} tile-steps, kernel == dense ref")
    return rows


def run(smoke: bool = False):
    iters = 8 if smoke else 16
    iters_cnn = 48 if smoke else 64
    iters_lm = 96 if smoke else 128
    print("per-pattern decode microbench (kernels.kernel_costs)")
    costs = bench_kernel_costs()
    cfg = reduce_config(RESNET18)
    # the win gate needs an informative accuracy axis: a weakly-trained CNN
    # has tiny logit margins, ANY pruning scrambles its predictions, and
    # the agreement proxy collapses to chance for every arm — so train to
    # convergence and calibrate on the task distribution (on random noise
    # the dense predictions are arbitrary to begin with)
    params = trained_cnn(cfg, steps=80)
    from repro.data.synthetic import image_batch
    images = image_batch(cfg, 32, seed=0, step=999)["images"]
    cnn_pack = (cfg, params, images)
    print("default-pattern transcript identity (patterns=None vs "
          "('unstructured',))")
    ident = bench_default_identity(cnn_pack, iters)
    print(f"pattern-aware vs unstructured-only search (cnn {iters_cnn} / "
          f"lm {iters_lm} trials, TPU stacks, dense-anchored)")
    win, winners = bench_pattern_win(cnn_pack, iters_cnn, iters_lm)
    print("measured decode factors through the Eq. 6 meas term")
    meas = bench_meas_term(costs["factors"])
    print("winning schedules through block_sparse_matmul (interpret)")
    executed = bench_executed(winners)
    save_json("sparsity_bench.json", {
        "smoke": smoke, "kernel_costs": costs, "default_identity": ident,
        "pattern_win": win, "meas_term": meas, "executed": executed})
    worst = min(r["gain"] for r in win)
    emit("sparsity_bench.pattern_win", 0.0,
         f"min hw-score gain {worst:.4f} over {len(win)} stacks; "
         f"nm decode factor {costs['factors']['nm']:.2f}x")
    return {"kernel_costs": costs, "pattern_win": win, "meas_term": meas,
            "executed": executed}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trial counts for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
