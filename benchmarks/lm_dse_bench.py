"""LM-workload DSE benchmark (DESIGN.md §11).

Three sections, saved to ``experiments/lm_dse_bench.json``:

  * ``stacks``    — per-config ``lm_layer_costs`` stack shapes (layer
    counts, prunable counts, analytic param counts) for all ten assigned
    architectures.
  * ``dse``       — vectorized ``incremental_dse`` vs the scalar ``_ref``
    oracle on deep LM stacks: identical results asserted, wall-clock and
    speedup reported. This is the hundreds-of-layers regime the vectorized
    engine's O(L) scans were built for (the CNN gate in ``dse_bench.py``
    tops out at ~60 layers).
  * ``partitions`` — 1/4/8-chip segment-table DP partitions of a sparse LM
    stack: sum-form (temporal) vs max-min (spatial steady-rate) objectives,
    with the max-min pick asserted never worse on ``steady_throughput``.

    PYTHONPATH=src:. python benchmarks/lm_dse_bench.py [--smoke]
"""
import argparse
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import ASSIGNED, get_config
from repro.core.dse import (incremental_dse, incremental_dse_ref,
                            partition_pipeline)
from repro.core.perf_model import (TPUModel, lm_block_bounds, lm_layer_costs,
                                   param_count, thin_cut_points,
                                   tile_quantize_sparsity)

DSE_MODELS = ["qwen3-0.6b", "mixtral-8x7b", "deepseek-v3-671b"]
PART_MODELS = ["mixtral-8x7b", "deepseek-v3-671b"]


def sparse_lm_stack(name: str, seq_len: int = 2048, seed: int = 1):
    """Sparse ``lm_layer_costs`` stack with tile-quantized weight sparsity
    in the paper's reported range (the TPU backend skips whole tiles only)."""
    layers = lm_layer_costs(get_config(name), seq_len=seq_len)
    rng = np.random.default_rng(seed)
    for l in layers:
        if l.prunable:
            l.s_w = l.s_w_tile = tile_quantize_sparsity(
                float(rng.uniform(0.1, 0.8)), l.m_dot, l.weight_count)
    return layers


def bench_stacks():
    rows = []
    for name in sorted(ASSIGNED):
        cfg = get_config(name)
        layers = lm_layer_costs(cfg)
        row = {"model": name, "layers": len(layers),
               "prunable": sum(1 for l in layers if l.prunable),
               "blocks": len(lm_block_bounds(layers)) + 1,
               "params_b": round(param_count(cfg) / 1e9, 2)}
        rows.append(row)
        print(f"  {name:18s} L={row['layers']:4d} "
              f"prunable={row['prunable']:4d} blocks={row['blocks']:3d} "
              f"params={row['params_b']:8.2f}B")
    return rows


def bench_dse(models, dse_iters: int, reps: int):
    rows = []
    for name in models:
        layers = sparse_lm_stack(name)
        tpu = TPUModel()
        new = incremental_dse(layers, tpu, tpu.budget, max_iters=dse_iters)
        ref = incremental_dse_ref(layers, tpu, tpu.budget,
                                  max_iters=dse_iters)
        assert new.designs == ref.designs and new.trace == ref.trace \
            and new.throughput == ref.throughput \
            and new.resource == ref.resource, name
        # same min-of-reps protocol on both sides: a noise spike in a
        # lone reference timing must not mask (or fake) a regression
        t_new = min(_t(lambda: incremental_dse(layers, tpu, tpu.budget,
                                               max_iters=dse_iters))
                    for _ in range(reps))
        t_ref = min(_t(lambda: incremental_dse_ref(layers, tpu, tpu.budget,
                                                   max_iters=dse_iters))
                    for _ in range(reps))
        row = {"model": name, "layers": len(layers), "dse_iters": dse_iters,
               "ref_ms": round(t_ref * 1e3, 1),
               "new_ms": round(t_new * 1e3, 1),
               "speedup": round(t_ref / t_new, 1)}
        rows.append(row)
        print(f"  {name:18s} L={row['layers']:4d} "
              f"ref={row['ref_ms']:8.1f}ms new={row['new_ms']:6.1f}ms "
              f"{row['speedup']:6.1f}x")
    return rows


def bench_partitions(models, chips_list, dse_iters: int, max_cuts: int,
                     batch: int = 64):
    rows = []
    for name in models:
        layers = sparse_lm_stack(name)
        cut_points = thin_cut_points(lm_block_bounds(layers), max_cuts)
        for chips in chips_list:
            tpu = TPUModel(chips=chips)
            kw = dict(n_parts=chips, batch=batch, dse_iters=dse_iters,
                      cut_points=cut_points)
            if chips == 1:
                t0 = time.perf_counter()
                p = partition_pipeline(layers, tpu, tpu.chip_budget, **kw)
                dt = time.perf_counter() - t0
                row = {"model": name, "chips": 1, "objective": p.objective,
                       "cuts": p.cuts, "wall_s": round(dt, 2),
                       "steady_tok_s": round(p.steady_throughput * tpu.freq, 2),
                       "amortized_tok_s": round(p.throughput * tpu.freq, 2)}
                rows.append(row)
                print(f"  {name:18s} x1  "
                      f"thr={row['amortized_tok_s']:8.1f} tok/s "
                      f"({dt:5.1f}s)")
                continue
            picks = {}
            for objective in ("sum", "maxmin"):
                t0 = time.perf_counter()
                p = partition_pipeline(layers, tpu, tpu.chip_budget,
                                       objective=objective, **kw)
                picks[objective] = p
                rows.append({
                    "model": name, "chips": chips, "objective": objective,
                    "cuts": p.cuts,
                    "wall_s": round(time.perf_counter() - t0, 2),
                    "steady_tok_s": round(p.steady_throughput * tpu.freq, 2),
                    "amortized_tok_s": round(p.throughput * tpu.freq, 2),
                    "dse_calls": p.dse_calls})
            sm, mm = picks["sum"], picks["maxmin"]
            assert mm.steady_throughput >= \
                sm.steady_throughput * (1 - 1e-12), (name, chips)
            gain = mm.steady_throughput / max(sm.steady_throughput, 1e-30)
            print(f"  {name:18s} x{chips}  "
                  f"steady sum={sm.steady_throughput * tpu.freq:8.1f} "
                  f"maxmin={mm.steady_throughput * tpu.freq:8.1f} tok/s "
                  f"({gain:.2f}x)  cuts sum={sm.cuts} maxmin={mm.cuts}")
    return rows


def _t(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(smoke: bool = False):
    dse_models = DSE_MODELS[:1] if smoke else DSE_MODELS
    part_models = PART_MODELS[:1] if smoke else PART_MODELS
    chips_list = (1, 4) if smoke else (1, 4, 8)
    dse_iters = 120 if smoke else 300
    max_cuts = 8 if smoke else 12
    print("lm_layer_costs stacks (all assigned archs)")
    stacks = bench_stacks()
    print("incremental_dse on LM stacks: scalar reference vs vectorized")
    dse_rows = bench_dse(dse_models, dse_iters=dse_iters,
                         reps=2 if smoke else 3)
    print(f"partition_pipeline on sparse LM stacks (chips={list(chips_list)})")
    part_rows = bench_partitions(part_models, chips_list,
                                 dse_iters=dse_iters, max_cuts=max_cuts)
    worst = min(r["speedup"] for r in dse_rows)
    save_json("lm_dse_bench.json", {
        "smoke": smoke, "stacks": stacks, "dse": dse_rows,
        "partitions": part_rows, "worst_speedup": worst})
    emit("lm_dse_bench.incremental_dse",
         sum(r["new_ms"] for r in dse_rows) * 1e3,
         f"worst={worst:.1f}x over {len(dse_rows)} LM stacks "
         f"(L={max(r['layers'] for r in dse_rows)})")
    assert worst >= 10.0, f"LM-stack DSE speedup regressed: {worst:.1f}x"
    return {"stacks": stacks, "dse": dse_rows, "partitions": part_rows}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced set for CI (one DSE model, 1/4-chip)")
    args = ap.parse_args()
    run(smoke=args.smoke)
