"""Fig. 4 analogue: per-layer (SPE count, MAC/SPE) allocation for a sparse
ResNet-18 workload — higher sparsity -> fewer MACs per SPE; later layers
(more filters) -> more parallel SPEs to hold the pipeline rate."""
import dataclasses

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import incremental_dse, incremental_dse_ref
from repro.core.perf_model import FPGAModel, LayerCost, cnn_layer_costs


def run(budget: int = 12234, seed: int = 0):
    hw = FPGAModel()
    rng = np.random.default_rng(seed)
    layers = []
    # the paper's Fig. 4 workload: 16 3x3 convs with per-layer sparsity stats
    for l in cnn_layer_costs(RESNET18):
        if l.kind == "conv" and l.m_dot % 9 == 0 and l.name != "stem" \
                and "proj" not in l.name:
            s_w = float(rng.uniform(0.3, 0.8))
            s_a = float(rng.uniform(0.2, 0.6))
            layers.append(dataclasses.replace(l, s_w=s_w, s_a=s_a))
    (res,), us = timed(lambda: (incremental_dse(layers, hw, budget,
                                                max_iters=4000),))
    # the scalar reference must agree exactly (and is the old wall-clock;
    # benchmarks/dse_bench.py reports the full old-vs-new comparison)
    ref, us_ref = timed(lambda: incremental_dse_ref(layers, hw, budget,
                                                    max_iters=4000))
    assert ref.designs == res.designs and ref.throughput == res.throughput
    table = []
    for l, d in zip(layers, res.designs):
        table.append({"layer": l.name, "s_pair": round(l.s_pair, 3),
                      "spe": d.spe, "mac_per_spe": d.macs_per_spe,
                      "dsp": d.spe * d.macs_per_spe})
        print(f"  {l.name:10s} S̄={l.s_pair:.2f} SPE={d.spe:5d} "
              f"N={d.macs_per_spe:4d}")
    # the full non-dominated (resource, throughput) frontier of the search —
    # one run yields the whole budget sweep (DESIGN.md §10)
    f = res.frontier
    frontier = [{"res": float(r), "thr": float(t),
                 "imgs_per_s": float(t) * hw.freq}
                for r, t in zip(f.res, f.thr)]
    print(f"  frontier: {len(f)} non-dominated points, "
          f"res [{f.res[0]:.0f}, {f.res[-1]:.0f}] DSP -> "
          f"thr [{f.thr[0] * hw.freq:.1f}, {f.thr[-1] * hw.freq:.1f}] img/s")
    for k in np.linspace(0, len(f) - 1, min(8, len(f))).astype(int):
        bar = "#" * max(1, int(40 * f.thr[k] / f.thr[-1]))
        print(f"    res={f.res[k]:7.0f} thr={f.thr[k] * hw.freq:9.1f} {bar}")
    save_json("fig4.json", {"rows": table, "throughput": res.throughput,
                            "resource": res.resource, "frontier": frontier})
    # qualitative check: among equal-shape layers, sparser => smaller N
    emit("fig4.dse_allocation", us,
         f"layers={len(layers)} thr={res.throughput * hw.freq:.0f}img/s "
         f"res={res.resource:.0f} vec_speedup={us_ref / max(us, 1e-9):.1f}x")
    return table


if __name__ == "__main__":
    run()
