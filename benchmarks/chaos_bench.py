"""Chaos & graceful-degradation gates (DESIGN.md §17), saved to
``experiments/chaos_bench.json``:

  * ``zero_fault`` — consuming the explicit no-op scenario
    (``FaultTrace.none()``) must be **bit-identical** to ``faults=None``
    on every consumer: ``SimReport`` (temporal + spatial chains),
    ``FleetReport`` (full per-request arrays + replica-cycles), and the
    real serve path's ``ServeReport`` transcript with the chaos kwargs at
    their defaults. Hard gate — the fault layer may not perturb a single
    bit of the pre-fault contracts.
  * ``engine`` — heap vs calendar stay bit-identical *under* faults
    (crash windows, stragglers, ICI degradation) and the extended
    conservation law ``busy + blocked + idle + down == horizon`` holds
    per node. Hard gate.
  * ``search`` — one replica crashes at the MMPP peak: the
    failure-aware ``autoscale_policy_search`` (simulating its trials
    under the fault set) must find a policy with strictly lower simulated
    p99 under that fault than the fault-blind search's winner. Hard gate.
  * ``degrade`` — same crash, deadline-bound traffic: a
    ``DegradationPolicy`` stepping down the sparsity frontier must shed
    strictly fewer requests than the non-degrading fleet at no extra
    replica cost. Hard gate.
  * ``replay`` — a frontier-degraded bucket schedule (rung step-scales
    priced by ``core.dse.degradation_ladder``, deadlines attached)
    replays **twin-identical** through the real
    ``ServeSession.serve_open_loop``. Hard gate.

    PYTHONPATH=src:. python benchmarks/chaos_bench.py [--smoke]
"""
import argparse

import numpy as np

from benchmarks.common import emit, save_json
from benchmarks.dse_bench import _sparse_workload as _sparse_cnn
from benchmarks.sim_bench import _sparse_lm
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import degradation_ladder, partition_pipeline
from repro.core.perf_model import FPGAModel, TPUModel
from repro.serve.fleet import (AutoscalePolicy, DegradationPolicy,
                               open_loop_schedule, simulate_fleet)
from repro.sim import (inject_faults, mmpp_trace, replica_loss,
                       request_rate, simulate_partition, zero_fault_trace)
from repro.sim.engine import _simulate_chain
from repro.sim.faults import NodeFaults
from repro.sim.slo import autoscale_policy_search

_SIM_FIELDS = ("completions", "latency", "busy", "blocked", "idle",
               "queue_mean", "queue_max", "down")
_FLEET_FIELDS = ("admissions", "completions", "latency", "assignment",
                 "routed_at", "shed_mask", "retries")
_FLEET_KW = dict(batch_slots=8, step_cycles=100.0, prefill_cycles=300.0)


def _identical(a, b, fields) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in fields)


def bench_zero_fault(smoke: bool):
    """``FaultTrace.none()`` == ``faults=None``, byte for byte, on every
    consumer — the regression gate that pins the pre-fault code paths."""
    rows = []
    # SimReport: temporal (FPGA) and spatial (TPU slice) chains
    tpu = TPUModel(chips=3)
    lm = _sparse_lm("qwen3-0.6b", 0)
    p_lm = partition_pipeline(lm, tpu, tpu.chip_budget, n_parts=3, batch=32,
                              dse_iters=100, objective="maxmin")
    cnn = _sparse_cnn(RESNET18, 1)
    fpga = FPGAModel()
    p_t = partition_pipeline(cnn, fpga, 4096.0, n_parts=3, batch=64,
                             reconfig_cycles=1e6, dse_iters=100)
    n_req = 300 if smoke else 800
    for tag, layers, hw, part, kw in (
            ("lm_spatial", lm, tpu, p_lm, {}),
            ("cnn_temporal", cnn, fpga, p_t, {"reconfig_cycles": 1e6})):
        rate = request_rate(part.steady_throughput if tag == "lm_spatial"
                            else part.throughput, 0.5, 32)
        tr = mmpp_trace(n_req, 0.6 * rate, 3.0 * rate,
                        dwell_base=4.0 / rate, dwell_burst=1.0 / rate,
                        sizes=32, seed=0)
        for eng in ("heap", "calendar"):
            ref = simulate_partition(layers, hw, part, tr, engine=eng, **kw)
            got = simulate_partition(layers, hw, part, tr, engine=eng,
                                     faults=zero_fault_trace(), **kw)
            same = _identical(ref, got, _SIM_FIELDS)
            rows.append({"consumer": f"sim/{tag}/{eng}", "identical": same})
            assert same, f"zero-fault perturbed SimReport: {tag}/{eng}"
    # FleetReport
    trf = mmpp_trace(1500 if smoke else 4000, 2e-4, 1.5e-2, dwell_base=3e5,
                     dwell_burst=8e4, sizes=[8, 16], seed=0)
    pol = AutoscalePolicy(min_replicas=1, max_replicas=3,
                          scale_up_backlog=1.0, scale_down_backlog=0.2)
    ref = simulate_fleet(trf, pol, **_FLEET_KW)
    got = simulate_fleet(trf, pol, faults=zero_fault_trace(), **_FLEET_KW)
    same = _identical(ref, got, _FLEET_FIELDS) \
        and got.replica_cycles == ref.replica_cycles \
        and got.timeline == ref.timeline
    rows.append({"consumer": "fleet", "identical": same})
    assert same, "zero-fault scenario perturbed the FleetReport"
    # real serve transcript: chaos kwargs at defaults change nothing
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.serve_loop import ServeSession, requests_from_trace
    from repro.sim.trace import Trace
    cfg = reduce_config(get_config("qwen3-0.6b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sess = ServeSession(api, params, batch_slots=4, S_max=40)
    sub = Trace(trf.arrivals[:12] - trf.arrivals[0], trf.sizes[:12],
                kind=trf.kind)
    reqs_a = requests_from_trace(sub, vocab_size=cfg.vocab_size,
                                 prompt_len=8, seed=0)
    reqs_b = requests_from_trace(sub, vocab_size=cfg.vocab_size,
                                 prompt_len=8, seed=0)
    ra = sess.serve_open_loop(reqs_a, step_cycles=100.0,
                              prefill_cycles=300.0)
    rb = sess.serve_open_loop(reqs_b, step_cycles=100.0,
                              prefill_cycles=300.0, step_schedule=None,
                              switch_cycles=0.0)
    same = (np.array_equal(ra.admissions, rb.admissions)
            and np.array_equal(ra.completions, rb.completions)
            and ra.outputs == rb.outputs and rb.shed == 0)
    rows.append({"consumer": "serve", "identical": same})
    assert same, "chaos kwargs at defaults perturbed the serve transcript"
    print(f"  zero_fault: {len(rows)} consumers bit-identical to "
          f"faults=None")
    return rows


def bench_faulted_engines(smoke: bool):
    """Heap vs calendar under injected faults: bit-identical reports and
    ``busy + blocked + idle + down == horizon`` per node."""
    rng = np.random.default_rng(0)
    trials = 8 if smoke else 20
    rows = []
    for trial in range(trials):
        m = int(rng.integers(1, 5))
        n = int(rng.integers(60, 160))
        arr = np.sort(rng.uniform(0, 5e4, n))
        sizes = rng.integers(1, 16, n).astype(np.int64)
        rates = rng.uniform(5e-3, 5e-2, m)
        service = [(lambda r: (lambda s: s / r))(r) for r in rates]
        caps = [10 ** 9] + [int(rng.integers(1, 4)) for _ in range(m - 1)]
        ft = inject_faults(m, 6e4, crash_rate=3e-4, restart_mean=2e3,
                           slow_rate=3e-4, slow_mean=3e3, slow_factor=0.5,
                           seed=trial)
        fx = NodeFaults(down=[ft.down_windows(u) for u in range(m)],
                        slow=[ft.slow_windows(u) for u in range(m)])
        heap = _simulate_chain(arr, sizes, service, caps, "heap", fx)
        cal = _simulate_chain(arr, sizes, service, caps, "calendar", fx)
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(heap, cal))
        comp, busy, blocked, idle, _, _, down = heap
        horizon = comp.max()
        cons = max(abs(busy[k] + blocked[k] + idle[k] + down[k] - horizon)
                   for k in range(m)) / max(horizon, 1.0)
        rows.append({"trial": trial, "nodes": m, "identical": same,
                     "down_cycles": float(sum(down)),
                     "conservation_rel_err": float(cons)})
        assert same, f"faulted engines diverged on trial {trial}"
        assert cons < 1e-9, f"conservation broken under faults: {cons:.2e}"
    fired = sum(1 for r in rows if r["down_cycles"] > 0)
    print(f"  engine: {trials} faulted chains bit-identical "
          f"(heap vs calendar), down>0 in {fired}, conservation holds")
    assert fired > 0, "fault set never displaced any cycles"
    return rows


def _crash_scenario(smoke: bool):
    n_req = 2500 if smoke else 6000
    tr = mmpp_trace(n_req, 2e-4, 1.5e-2, dwell_base=3e5, dwell_burst=8e4,
                    sizes=[8, 16], seed=0)
    peak = float(np.median(tr.arrivals))
    return tr, replica_loss(0, peak, peak + 1.5e6)


def bench_failure_aware_search(smoke: bool):
    """One replica lost at the MMPP peak: searching *under* the fault set
    must beat searching blind, measured under that same fault."""
    tr, ft = _crash_scenario(smoke)
    trials = 16 if smoke else 32
    chaos = dict(faults=ft, deadline_cycles=4e5)
    pol_b, _, _ = autoscale_policy_search(tr, max_replicas=3,
                                          n_trials=trials, seed=0,
                                          **_FLEET_KW)
    pol_a, rep_a, base = autoscale_policy_search(tr, max_replicas=3,
                                                 n_trials=trials, seed=0,
                                                 **chaos, **_FLEET_KW)
    rep_b = simulate_fleet(tr, pol_b, **chaos, **_FLEET_KW)
    p99_b = rep_b.p99 if rep_b.completed else float("inf")
    p99_a = rep_a.p99 if rep_a.completed else float("inf")
    print(f"  search: fault-blind winner under crash p99={p99_b:.4e} "
          f"shed={rep_b.shed} | failure-aware p99={p99_a:.4e} "
          f"shed={rep_a.shed}")
    assert p99_a < p99_b, \
        (f"failure-aware search must strictly beat the fault-blind pick "
         f"under the fault set: {p99_a:.4e} vs {p99_b:.4e}")
    assert rep_a.shed <= rep_b.shed
    return {"blind_p99": p99_b, "blind_shed": int(rep_b.shed),
            "aware_p99": p99_a, "aware_shed": int(rep_a.shed),
            "static_best": base["static_best"],
            "aware_policy": {"min_replicas": pol_a.min_replicas,
                             "scale_up_backlog": pol_a.scale_up_backlog}}


def bench_degradation(smoke: bool):
    """Deadline-bound traffic through the crash: stepping down the
    frontier ladder must shed strictly fewer requests at no extra
    replica cost."""
    n_req = 2000 if smoke else 5000
    tr = mmpp_trace(n_req, 2e-4, 2e-2, dwell_base=2e5, dwell_burst=1.5e5,
                    sizes=[8, 16], seed=0)
    peak = float(np.median(tr.arrivals))
    ft = replica_loss(1, peak, peak + 2e6)
    kw = dict(faults=ft, deadline_cycles=2e5, **_FLEET_KW)
    plain = simulate_fleet(tr, AutoscalePolicy.static(2), **kw)
    deg = DegradationPolicy(ladder=(1.0, 0.6, 0.35), degrade_backlog=3.0,
                            recover_backlog=0.5, dwell_cycles=1e5,
                            switch_cycles=1e4)
    soft = simulate_fleet(tr, AutoscalePolicy.static(2), degradation=deg,
                          **kw)
    moves = len(soft.rung_timeline) - 1
    print(f"  degrade: plain shed={plain.shed} vs degraded "
          f"shed={soft.shed} ({moves} rung moves), cost "
          f"{soft.replica_cycles:.3e} vs {plain.replica_cycles:.3e}")
    assert soft.shed < plain.shed, \
        f"degradation must shed fewer: {soft.shed} vs {plain.shed}"
    assert soft.replica_cycles <= plain.replica_cycles * (1 + 1e-9), \
        "degradation must not cost extra replica-cycles"
    return {"plain_shed": int(plain.shed), "degraded_shed": int(soft.shed),
            "rung_moves": moves,
            "plain_cost": plain.replica_cycles,
            "degraded_cost": soft.replica_cycles,
            "rung_timeline": [(float(a), int(b))
                              for a, b in soft.rung_timeline]}


def bench_degraded_replay(smoke: bool):
    """A frontier-degraded schedule is real: rung step-scales priced by
    ``degradation_ladder`` on a sparse CNN stack become a
    ``step_schedule``, and the degraded, deadline-bound bucket schedule
    replays twin-identical through the real serve path."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.serve_loop import Request, ServeSession

    rungs = degradation_ladder(_sparse_cnn(RESNET18, 1), FPGAModel(),
                               budget=4096.0, s_extra=(0.0, 0.2, 0.4))
    ladder = tuple(r.step_scale for r in rungs)
    cfg = reduce_config(get_config("qwen3-0.6b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sess = ServeSession(api, params, batch_slots=4, S_max=40)
    rng = np.random.default_rng(5)
    n = 16 if smoke else 32
    arr = np.cumsum(rng.exponential(400.0, n)).astype(float)
    new = rng.integers(4, 20, n).astype(float)
    dls = arr + rng.uniform(2e3, 2e4, n)
    # degrade two rungs down mid-trace, recover near the end
    sched = [(0.0, ladder[0]), (float(arr[n // 3]), ladder[1]),
             (float(arr[n // 2]), ladder[2]), (float(arr[-3]), ladder[0])]
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new=int(new[i]), arrival=float(arr[i]),
                    deadline=float(dls[i])) for i in range(n)]
    rep = sess.serve_open_loop(reqs, step_cycles=60.0, prefill_cycles=180.0,
                               step_schedule=sched, switch_cycles=90.0)
    adm, comp = open_loop_schedule(arr, new, batch_slots=sess.B,
                                   step_cycles=60.0, prefill_cycles=180.0,
                                   deadlines=dls, step_schedule=sched,
                                   switch_cycles=90.0)
    twin = (np.array_equal(rep.admissions, adm)
            and np.array_equal(rep.completions, comp))
    print(f"  replay: {n} requests, ladder={tuple(round(s, 3) for s in ladder)}, "
          f"shed={rep.shed}, switch_stalls={rep.switch_stalls}, "
          f"twin-identical={twin}")
    assert twin, "degraded schedule diverged from the real serve path"
    assert rep.switch_stalls > 0, "the rung schedule never actually moved"
    return {"requests": n, "ladder": list(ladder), "shed": int(rep.shed),
            "switch_stalls": int(rep.switch_stalls),
            "twin_identical": twin}


def run(smoke: bool = False):
    print("chaos: zero-fault scenarios bit-identical to faults=None")
    zero_rows = bench_zero_fault(smoke)
    print("chaos: engine bit-identity + conservation under faults")
    engine_rows = bench_faulted_engines(smoke)
    print("chaos: failure-aware vs fault-blind autoscale search")
    search_row = bench_failure_aware_search(smoke)
    print("chaos: graceful degradation vs hard shedding")
    degrade_row = bench_degradation(smoke)
    print("chaos: degraded schedule through the real serve path")
    replay_row = bench_degraded_replay(smoke)
    payload = {"smoke": smoke, "zero_fault": zero_rows,
               "engine": engine_rows, "search": search_row,
               "degrade": degrade_row, "replay": replay_row}
    save_json("chaos_bench.json", payload)
    emit("chaos_bench.zero_fault", 0.0,
         f"{len(zero_rows)} consumers bit-identical")
    emit("chaos_bench.engine", 0.0,
         f"{len(engine_rows)} faulted chains bit-identical, "
         f"conservation holds")
    emit("chaos_bench.search", 0.0,
         f"failure-aware p99={search_row['aware_p99']:.3e} < "
         f"fault-blind {search_row['blind_p99']:.3e} under crash")
    emit("chaos_bench.degrade", 0.0,
         f"shed {degrade_row['degraded_shed']} vs "
         f"{degrade_row['plain_shed']} at no extra cost")
    emit("chaos_bench.replay", 0.0,
         f"twin-identical, {replay_row['switch_stalls']} rung stalls, "
         f"{replay_row['shed']} shed")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace lengths / trial counts for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
