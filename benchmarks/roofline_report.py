"""Assemble the §Roofline table from experiments/dryrun.json."""
import json
import os

from benchmarks.common import RESULTS_DIR, emit


def load():
    path = os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def run(mesh: str = "pod16x16"):
    res = load()
    rows = []
    for key, rec in sorted(res.items()):
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    if not rows:
        print("no dry-run results yet; run python -m repro.launch.dryrun --all")
        return []
    print(f"{'arch':18s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} "
          f"{'HBM GiB':>8s}")
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{100 * r['roofline_frac']:6.1f}% {r['hbm_total_gib']:8.1f}")
    emit("roofline.cells", 0.0, f"n={len(rows)} mesh={mesh}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    run(**vars(ap.parse_args()))
