"""Benchmark harness — one entry per paper table/figure + the roofline report.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows
and writes ``experiments/bench_summary.json`` — one machine-readable row
per executed job (name, wall seconds, pass/fail, and the scalar metrics
pulled off the job's returned payload) so CI and the report tooling can
consume the run without scraping stdout. ``--list`` prints the registered
job names and exits. Flags scale the heavier searches (--full reproduces
the paper's 96-iteration budget; default keeps a single-core run under
~15 minutes).
"""
import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _scalar_metrics(payload, prefix: str = "", depth: int = 2) -> dict:
    """The payload's top-level scalars (one nesting level of dicts is
    flattened as ``outer.inner``) — the derived numbers a dashboard would
    plot, without dragging whole per-row tables into the summary."""
    out = {}
    if not isinstance(payload, dict):
        return out
    for k, v in payload.items():
        if isinstance(v, bool) or isinstance(v, (int, float, str)):
            out[prefix + str(k)] = v
        elif isinstance(v, dict) and depth > 1:
            for kk, vv in v.items():
                if isinstance(vv, (bool, int, float, str)):
                    out[f"{prefix}{k}.{kk}"] = vv
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-budget searches (96 TPE iters)")
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,fig4,fig6,fig1,fig5,table2,"
                         "roofline,dse,lm_dse,search,sim,fleet,sparsity,"
                         "chaos,obs")
    ap.add_argument("--list", action="store_true",
                    help="print registered job names and exit")
    args = ap.parse_args()
    iters = 96 if args.full else 10
    t2_iters = 24 if args.full else 8
    smoke = not args.full

    from benchmarks import (chaos_bench, dse_bench, fig1_frontier,
                            fig4_dse_allocation, fig5_search_compare,
                            fig6_speedup, fleet_bench, kernels_bench,
                            lm_dse_bench, obs_bench, roofline_report,
                            search_bench, sim_bench, sparsity_bench,
                            table2_models)
    from benchmarks.common import save_json
    jobs = [
        ("kernels", lambda: kernels_bench.run()),
        ("fig4", lambda: fig4_dse_allocation.run()),
        ("fig6", lambda: fig6_speedup.run()),
        ("fig1", lambda: fig1_frontier.run(iters=max(iters // 2, 8))),
        ("fig5", lambda: fig5_search_compare.run(iters=iters)),
        ("table2", lambda: table2_models.run(iters=t2_iters)),
        ("roofline", lambda: roofline_report.run()),
        # engine/system gates (hard asserts; --full drops the smoke subsets)
        ("dse", lambda: dse_bench.run()),
        ("lm_dse", lambda: lm_dse_bench.run(smoke=smoke)),
        ("search", lambda: search_bench.run(smoke=smoke)),
        ("sim", lambda: sim_bench.run(smoke=smoke)),
        ("fleet", lambda: fleet_bench.run(smoke=smoke)),
        ("sparsity", lambda: sparsity_bench.run(smoke=smoke)),
        ("chaos", lambda: chaos_bench.run(smoke=smoke)),
        ("obs", lambda: obs_bench.run(smoke=smoke)),
    ]
    if args.list:
        for name, _ in jobs:
            print(name)
        return
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    summary = []
    for name, job in jobs:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            payload = job()
            summary.append({"job": name, "ok": True,
                            "wall_s": round(time.perf_counter() - t0, 3),
                            "metrics": _scalar_metrics(payload)})
        except Exception:                                     # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
            summary.append({"job": name, "ok": False,
                            "wall_s": round(time.perf_counter() - t0, 3),
                            "metrics": {}})
    save_json("bench_summary.json",
              {"full": args.full, "failures": failures, "jobs": summary})
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
