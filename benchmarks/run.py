"""Benchmark harness — one entry per paper table/figure + the roofline report.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows.
Flags scale the heavier searches (--full reproduces the paper's 96-iteration
budget; default keeps a single-core run under ~15 minutes).
"""
import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-budget searches (96 TPE iters)")
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,fig4,fig6,fig5,fig1,table2,"
                         "roofline,dse,lm_dse,search,sim,fleet,sparsity,"
                         "chaos")
    args = ap.parse_args()
    iters = 96 if args.full else 10
    t2_iters = 24 if args.full else 8
    smoke = not args.full

    from benchmarks import (chaos_bench, dse_bench, fig1_frontier,
                            fig4_dse_allocation, fig5_search_compare,
                            fig6_speedup, fleet_bench, kernels_bench,
                            lm_dse_bench, roofline_report, search_bench,
                            sim_bench, sparsity_bench, table2_models)
    jobs = [
        ("kernels", lambda: kernels_bench.run()),
        ("fig4", lambda: fig4_dse_allocation.run()),
        ("fig6", lambda: fig6_speedup.run()),
        ("fig1", lambda: fig1_frontier.run(iters=max(iters // 2, 8))),
        ("fig5", lambda: fig5_search_compare.run(iters=iters)),
        ("table2", lambda: table2_models.run(iters=t2_iters)),
        ("roofline", lambda: roofline_report.run()),
        # engine/system gates (hard asserts; --full drops the smoke subsets)
        ("dse", lambda: dse_bench.run()),
        ("lm_dse", lambda: lm_dse_bench.run(smoke=smoke)),
        ("search", lambda: search_bench.run(smoke=smoke)),
        ("sim", lambda: sim_bench.run(smoke=smoke)),
        ("fleet", lambda: fleet_bench.run(smoke=smoke)),
        ("sparsity", lambda: sparsity_bench.run(smoke=smoke)),
        ("chaos", lambda: chaos_bench.run(smoke=smoke)),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, job in jobs:
        if only and name not in only:
            continue
        try:
            job()
        except Exception:                                     # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
