"""Fig. 1 analogue: the accuracy vs operation-density trade-off frontier
traced by the hardware-aware search (MobileNetV2, the paper's Fig. 1 model)."""
import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed, trained_cnn
from repro.configs.paper_cnns import MOBILENETV2
from repro.core.hass import CNNEvaluator, hass_search
from repro.core.perf_model import FPGAModel


def run(iters: int = 16, img_res: int = 64, seed: int = 0):
    cfg = dataclasses.replace(MOBILENETV2, img_res=img_res)
    params = trained_cnn(cfg, steps=20)
    images = jax.random.normal(jax.random.PRNGKey(seed),
                               (8, img_res, img_res, 3))
    ev = CNNEvaluator(cfg, params, images, FPGAModel(), budget=5261,
                      dse_iters=400, cost_cfg=MOBILENETV2)
    res, us = timed(lambda: hass_search(ev, len(ev.prunable), iters=iters,
                                        hardware_aware=True, seed=seed))
    pts = [{"density": 1.0 - t.metrics["spa"], "acc": t.metrics["acc"],
            "eff": t.metrics["eff"]} for t in res.trials]
    # pareto frontier (max acc per density bucket)
    pareto = []
    for p in sorted(pts, key=lambda p: p["density"]):
        if not pareto or p["acc"] > pareto[-1]["acc"]:
            pareto.append(p)
    save_json("fig1.json", {"points": pts, "pareto": pareto})
    emit("fig1.frontier", us,
         f"points={len(pts)} best_acc@dens<0.5="
         f"{max((p['acc'] for p in pts if p['density'] < 0.5), default=0):.3f}")
    return pts


if __name__ == "__main__":
    run()
