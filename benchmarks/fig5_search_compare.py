"""Fig. 5 analogue: hardware-aware vs software-metrics-only sparsity search
on ResNet-18 — computation efficiency (throughput/area) of the best design
so far, per TPE iteration. The paper runs 96 iterations; --iters controls it
(the default keeps the tee'd benchmark run short; EXPERIMENTS.md records the
96-iteration run)."""
import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timed, trained_cnn
from repro.configs import reduce_config
from repro.configs.paper_cnns import RESNET18
from repro.core.hass import CNNEvaluator, hass_search
from repro.core.perf_model import FPGAModel


def run(iters: int = 16, img_res: int = 64, seed: int = 0,
        budget: int = 12234, batch_size: int = 8, chips: int = 1):
    """``batch_size``: TPE proposals evaluated per vmapped prune+forward
    round (DESIGN.md §8); ``None``/0 falls back to the serial ask/tell loop.
    ``chips > 1`` additionally runs the partitioned multi-chip TPU DSE
    (segment-table DP, ICI-aware switches — DESIGN.md §10) on the best
    hardware-aware proposal's measured sparsities.
    """
    cfg = dataclasses.replace(RESNET18, img_res=img_res)
    params = trained_cnn(cfg, steps=20)
    images = jax.random.normal(jax.random.PRNGKey(seed),
                               (8, img_res, img_res, 3))
    ev = CNNEvaluator(cfg, params, images, FPGAModel(), budget=budget,
                      dse_iters=600, cost_cfg=RESNET18)

    def go(hardware_aware):
        return hass_search(ev, len(ev.prunable), iters=iters,
                           hardware_aware=hardware_aware, seed=seed,
                           batch_size=batch_size or None)

    hw_res, us_hw = timed(lambda: go(True))
    sw_res, us_sw = timed(lambda: go(False))
    payload = {
        "iters": iters,
        "batch_size": batch_size,
        "trials_per_s": 2 * iters / ((us_hw + us_sw) / 1e6),
        "hw_eff_curve": hw_res.running_best("eff"),
        "sw_eff_curve": sw_res.running_best("eff"),
        "hw_best": hw_res.best_metrics, "sw_best": sw_res.best_metrics,
    }
    if chips and chips > 1:
        from repro.core.dse import partition_pipeline
        from repro.core.perf_model import TPUModel
        tpu = TPUModel(chips=chips)
        layers = ev.sparse_layers(hw_res.best_x)
        part = partition_pipeline(layers, tpu, tpu.chip_budget,
                                  n_parts=chips, batch=256)
        payload["multi_chip"] = {
            "chips": chips, "cuts": part.cuts,
            "parts": len(part.cuts) + 1,
            "time_per_batch": part.time_per_batch,
            "imgs_per_s": part.throughput * tpu.freq,
            "steady_imgs_per_s": part.steady_throughput * tpu.freq,
            "dse_calls": part.dse_calls,
        }
    save_json("fig5.json", payload)
    gain = hw_res.best_metrics["eff"] / max(sw_res.best_metrics["eff"], 1e-9)
    emit("fig5.search_compare", us_hw + us_sw,
         f"hw_eff={hw_res.best_metrics['eff']:.1f} "
         f"sw_eff={sw_res.best_metrics['eff']:.1f} gain={gain:.2f}x "
         f"({payload['trials_per_s']:.2f} trials/s @ batch={batch_size})")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="proposals per vmapped evaluation round (0=serial)")
    ap.add_argument("--chips", type=int, default=1,
                    help="TPU chips for the partitioned multi-chip DSE "
                         "(1 = skip)")
    args = ap.parse_args()
    run(iters=args.iters, batch_size=args.batch_size, chips=args.chips)
