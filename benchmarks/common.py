"""Shared benchmark utilities."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import json

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeat: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def trained_cnn(cfg, steps: int = 30, batch: int = 16, lr: float = 2e-3,
                seed: int = 0):
    """Lightly train a CNN on the synthetic cluster task so magnitude pruning
    has structure to exploit (no ImageNet in-container; DESIGN.md §5)."""
    from repro.data.synthetic import image_batch
    from repro.models import cnn

    rng = jax.random.PRNGKey(seed)
    params = cnn.init_params(cfg, rng)

    @jax.jit
    def step(params, batch_):
        def lfn(p):
            return cnn.loss(cfg, p, batch_)[0]
        l, g = jax.value_and_grad(lfn)(params)
        params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
        return params, l

    for i in range(steps):
        params, l = step(params, image_batch(cfg, batch, seed=seed, step=i))
    return params
