"""Fleet-serving gates (DESIGN.md §14), saved to
``experiments/fleet_bench.json``:

  * ``engine`` — the calendar-queue event engine must reproduce the
    binary-heap engine **bit-identically** (every ``SimReport`` field,
    ``np.array_equal``, no tolerance) across the gated spatial and
    temporal scenarios x traffic shapes, and must be >= 10x faster on a
    >= 1M-event diurnal trace — the property that makes simulation cheap
    enough to sit inside a TPE policy search. Hard gates.
  * ``policy`` — ``autoscale_policy_search`` on a seeded bursty (MMPP)
    scenario whose peak saturates small fleets: the searched policy must
    achieve strictly lower simulated p99 than the best static replica
    count, or equal p99 at strictly lower replica-cycles. Hard gate.
    (A diurnal variant is reported alongside, ungated.)
  * ``replay`` — the winning policy's busiest replica stream replays
    through the *real* open-loop serve path (tiny CPU transformer):
    the ``ServeReport`` admission/completion clocks must equal the
    timing twin's bit for bit, and the replayed tail must stay inside
    the SLO the search was scored against. Hard gate.

    PYTHONPATH=src:. python benchmarks/fleet_bench.py [--smoke]
"""
import argparse
import time

import numpy as np

from benchmarks.common import emit, save_json
from benchmarks.dse_bench import _sparse_workload as _sparse_cnn
from benchmarks.sim_bench import _sparse_lm
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import partition_pipeline
from repro.core.perf_model import FPGAModel, TPUModel
from repro.serve.fleet import open_loop_schedule
from repro.sim import (diurnal_trace, mmpp_trace, poisson_trace,
                       request_rate, simulate_partition)
from repro.sim.engine import _simulate_chain
from repro.sim.slo import SLO, autoscale_policy_search
from repro.sim.trace import Trace, backlogged_trace

_REPORT_FIELDS = ("completions", "latency", "busy", "blocked", "idle",
                  "queue_mean", "queue_max", "down")


def _identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in _REPORT_FIELDS)


def bench_engine_identity(smoke: bool):
    """Calendar vs heap: bit-identical ``SimReport`` on every gated
    scenario (spatial chains with finite queues + backpressure, the
    temporal single-executor schedule, all traffic shapes)."""
    scenarios = []
    tpu = TPUModel(chips=3)
    lm = _sparse_lm("qwen3-0.6b", 0)
    p_lm = partition_pipeline(lm, tpu, tpu.chip_budget, n_parts=3, batch=32,
                              dse_iters=100, objective="maxmin")
    scenarios.append(("lm_spatial", lm, tpu, p_lm, None))
    cnn = _sparse_cnn(RESNET18, 1)
    fpga = FPGAModel()
    p_t = partition_pipeline(cnn, fpga, 4096.0, n_parts=3, batch=64,
                             reconfig_cycles=1e6, dse_iters=100)
    scenarios.append(("cnn_temporal", cnn, fpga, p_t, 1e6))
    n_req = 300 if smoke else 800
    rows = []
    for tag, layers, hw, part, reconfig in scenarios:
        rate = request_rate(part.steady_throughput
                            if reconfig is None else part.throughput,
                            0.5, 32)
        traces = {
            "poisson": poisson_trace(n_req, rate, sizes=32, seed=0),
            "mmpp": mmpp_trace(n_req, 0.6 * rate, 3.0 * rate,
                               dwell_base=4.0 / rate, dwell_burst=1.0 / rate,
                               sizes=32, seed=0),
            "diurnal": diurnal_trace(n_req, 0.5 * rate, 1.8 * rate,
                                     period=50.0 / rate, sizes=32, seed=0),
            "backlogged": backlogged_trace(n_req, 32),
        }
        kw = {} if reconfig is None else {"reconfig_cycles": reconfig}
        for kind, tr in traces.items():
            for q_depth in (1, 4):
                a = simulate_partition(layers, hw, part, tr, q_depth=q_depth,
                                       engine="heap", **kw)
                b = simulate_partition(layers, hw, part, tr, q_depth=q_depth,
                                       engine="calendar", **kw)
                same = _identical(a, b)
                cons = np.max(np.abs(np.asarray(a.busy) + a.blocked + a.idle
                                     - a.horizon)) / max(a.horizon, 1.0)
                rows.append({"scenario": tag, "trace": kind,
                             "q_depth": q_depth, "identical": same,
                             "conservation_rel_err": float(cons)})
                assert same, f"engine mismatch: {tag}/{kind}/q={q_depth}"
                assert cons < 1e-9, \
                    f"time conservation broken: {tag}/{kind} err={cons:.2e}"
    print(f"  engine: {len(rows)} scenario x trace x depth combos, all "
          f"SimReport fields bit-identical (heap vs calendar)")
    return rows


def bench_engine_speedup(smoke: bool):
    """>= 10x on a >= 1M-event diurnal trace through a single executor —
    the shape a policy search simulates (temporal M=1 fast path)."""
    n = 500_000                      # 1M events (one arrival + one finish)
    tr = diurnal_trace(n, 1e-5, 4e-5, 1e7, sizes=8, seed=0)
    rates = [1e-4, 1.3e-4]
    service = [lambda sz: sum(sz / r for r in rates) + 1e5]
    caps = [n + 1]
    t0 = time.perf_counter()
    cal = _simulate_chain(tr.arrivals, tr.sizes, service, caps,
                          engine="calendar")
    t_cal = time.perf_counter() - t0
    t0 = time.perf_counter()
    heap = _simulate_chain(tr.arrivals, tr.sizes, service, caps,
                           engine="heap")
    t_heap = time.perf_counter() - t0
    same = all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(heap, cal))
    speedup = t_heap / t_cal
    print(f"  speedup: {2 * n} events, heap {t_heap:.2f}s vs calendar "
          f"{t_cal:.3f}s -> {speedup:.1f}x, bit-identical={same}")
    assert same, "calendar engine diverged from heap on the 1M-event trace"
    assert speedup >= 10.0, \
        f"calendar speedup regressed: {speedup:.1f}x < 10x"
    return {"events": 2 * n, "heap_s": t_heap, "calendar_s": t_cal,
            "speedup": speedup}


def bench_policy(smoke: bool):
    """The autoscaling win: searched policy vs best static replica count
    on a bursty MMPP trace whose peaks saturate small fleets (peak rate
    ~3.5x one replica's admission capacity) and whose troughs are sparse.
    Deterministic: seeded trace, deterministic controller + TPE."""
    kw = dict(batch_slots=8, step_cycles=100.0, prefill_cycles=300.0)
    n_req = 2000 if smoke else 6000
    trials = 16 if smoke else 32
    tr = mmpp_trace(n_req, 2e-4, 1.5e-2, dwell_base=3e5, dwell_burst=8e4,
                    sizes=[8, 16], seed=0)
    slo = None   # relative gate vs static; replay adds the absolute check
    pol, rep, base = autoscale_policy_search(tr, max_replicas=4,
                                             n_trials=trials, seed=0, **kw)
    p99_s, cost_s = base[base["static_best"]]
    win = (rep.p99 < p99_s) or (rep.p99 <= p99_s
                                and rep.replica_cycles < cost_s)
    print(f"  policy[mmpp]: static best R={base['static_best']} "
          f"p99={p99_s:.3e} cost={cost_s:.3e} | searched p99={rep.p99:.3e} "
          f"cost={rep.replica_cycles:.3e} "
          f"({rep.replica_cycles / cost_s:.0%} of static)")
    assert win, ("searched policy must beat the best static replica count: "
                 f"p99 {rep.p99:.3e} vs {p99_s:.3e}, cost "
                 f"{rep.replica_cycles:.3e} vs {cost_s:.3e}")
    # diurnal variant, reported ungated
    trd = diurnal_trace(n_req, 2e-5, 1.2e-2, 4e5, sizes=[8, 16], seed=0)
    pol_d, rep_d, base_d = autoscale_policy_search(
        trd, max_replicas=4, n_trials=trials, seed=0, **kw)
    p99_sd, cost_sd = base_d[base_d["static_best"]]
    print(f"  policy[diurnal]: static p99={p99_sd:.3e} cost={cost_sd:.3e} | "
          f"searched p99={rep_d.p99:.3e} cost={rep_d.replica_cycles:.3e}")
    row = {"trace": {"kind": tr.kind, "requests": len(tr)},
           "static": {str(r): {"p99": base[r][0], "cost": base[r][1]}
                      for r in range(1, 5)},
           "static_best": base["static_best"],
           "searched": {"p99": rep.p99, "cost": rep.replica_cycles,
                        "policy": {"min_replicas": pol.min_replicas,
                                   "max_replicas": pol.max_replicas,
                                   "scale_up_backlog": pol.scale_up_backlog,
                                   "scale_down_backlog":
                                       pol.scale_down_backlog,
                                   "boundary_cycles": pol.boundary_cycles,
                                   "admit_depth": pol.admit_depth}},
           "diurnal": {"static_p99": p99_sd, "static_cost": cost_sd,
                       "searched_p99": rep_d.p99,
                       "searched_cost": rep_d.replica_cycles}}
    return row, (pol, rep, tr, p99_s, kw)


def bench_replay(smoke: bool, winner):
    """The winning policy's schedule is real: its busiest replica's
    request stream replays through ``ServeSession.serve_open_loop`` on a
    tiny CPU transformer. The real session's admission/completion clocks
    must equal the timing twin's bit for bit, and the replayed tail must
    stay within the SLO (the best static fleet's p99 — the target the
    search was required not to regress)."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.serve_loop import ServeSession, requests_from_trace

    pol, rep, tr, p99_s, kw = winner
    n_replay = 12 if smoke else 24
    counts = np.bincount(rep.assignment, minlength=pol.max_replicas)
    busiest = int(np.argmax(counts))
    idx = np.flatnonzero(rep.assignment == busiest)[:n_replay]
    sub = Trace(rep.routed_at[idx] - rep.routed_at[idx].min(),
                tr.sizes[idx], kind=tr.kind)
    cfg = reduce_config(get_config("qwen3-0.6b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sess = ServeSession(api, params, batch_slots=kw["batch_slots"],
                        S_max=int(8 + max(sub.sizes) + 8))
    reqs = requests_from_trace(sub, vocab_size=cfg.vocab_size,
                               prompt_len=8, seed=0)
    srep = sess.serve_open_loop(reqs, step_cycles=kw["step_cycles"],
                                prefill_cycles=kw["prefill_cycles"])
    adm, comp = open_loop_schedule(sub.arrivals, sub.sizes,
                                   batch_slots=kw["batch_slots"],
                                   step_cycles=kw["step_cycles"],
                                   prefill_cycles=kw["prefill_cycles"])
    twin = (np.array_equal(srep.admissions, adm)
            and np.array_equal(srep.completions, comp))
    slo = SLO(target=float(p99_s), quantile=99.0)
    print(f"  replay: replica {busiest}, {len(idx)} requests through the "
          f"real serve path: twin-identical={twin}, p99={srep.p99:.3e} "
          f"(SLO {slo.target:.3e})")
    assert twin, "real serve path diverged from the fleet timing twin"
    assert srep.p99 <= slo.target, \
        f"replayed p99 {srep.p99:.3e} violates the SLO {slo.target:.3e}"
    return {"replica": busiest, "requests": len(idx),
            "twin_identical": twin, "p99": srep.p99,
            "slo_target": slo.target,
            "decode_steps": srep.decode_steps, "prefills": srep.prefills}


def run(smoke: bool = False):
    print("fleet serving: calendar-queue engine identity (heap reference)")
    engine_rows = bench_engine_identity(smoke)
    print("calendar-queue speedup on a 1M-event diurnal trace")
    speed_row = bench_engine_speedup(smoke)
    print("autoscale policy search vs static fleets")
    policy_row, winner = bench_policy(smoke)
    print("winning policy through the real open-loop serve path")
    replay_row = bench_replay(smoke, winner)
    payload = {"smoke": smoke, "engine_identity": engine_rows,
               "engine_speedup": speed_row, "policy": policy_row,
               "replay": replay_row}
    save_json("fleet_bench.json", payload)
    emit("fleet_bench.engine", 0.0,
         f"bit-identical over {len(engine_rows)} combos; "
         f"{speed_row['speedup']:.1f}x on {speed_row['events']} events")
    emit("fleet_bench.policy", 0.0,
         f"searched p99={policy_row['searched']['p99']:.3e} at "
         f"{policy_row['searched']['cost'] / policy_row['static'][str(policy_row['static_best'])]['cost']:.0%}"
         f" of the best static fleet's replica-cycles")
    emit("fleet_bench.replay", 0.0,
         f"twin-identical, p99={replay_row['p99']:.3e} <= "
         f"SLO {replay_row['slo_target']:.3e}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace lengths / trial counts for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
