"""Vectorized-DSE benchmark: old (scalar reference) vs new (array-native)
``incremental_dse`` wall-clock on the paper's five CNN workloads, plus
batched-vs-serial HASS search-engine throughput (trials/sec).

The vectorized engine is required to be *identical* (designs, throughput,
resource, trace — asserted here and property-tested in
tests/test_dse_equivalence.py) and >= 10x faster; this benchmark is the
acceptance gate.

    PYTHONPATH=src python benchmarks/dse_bench.py
"""
import time

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.configs.paper_cnns import (MOBILENETV2, MOBILENETV3L, MOBILENETV3S,
                                      RESNET18, RESNET50)
from repro.core.dse import (incremental_dse, incremental_dse_ref,
                            partition_pipeline, partition_pipeline_sa)
from repro.core.hass import hass_search
from repro.core.perf_model import FPGAModel, TPUModel, cnn_layer_costs

PAPER_CNNS = [("resnet18", RESNET18), ("resnet50", RESNET50),
              ("mobilenetv2", MOBILENETV2), ("mobilenetv3s", MOBILENETV3S),
              ("mobilenetv3l", MOBILENETV3L)]


def _sparse_workload(cfg, seed: int = 1):
    """Per-layer sparsity stats in the paper's reported range (§VI)."""
    rng = np.random.default_rng(seed)
    layers = cnn_layer_costs(cfg)
    for l in layers:
        l.s_w = float(rng.uniform(0.1, 0.8))
        l.s_a = float(rng.uniform(0.1, 0.6))
        l.s_w_tile = float(rng.uniform(0.0, 0.4))
    return layers


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_dse(reps: int = 5, ref_reps: int = 2):
    rows = []
    for name, cfg in PAPER_CNNS:
        layers = _sparse_workload(cfg)
        for hw_name, hw, budget in (("fpga", FPGAModel(), 12288.0),
                                    ("tpu", TPUModel(), TPUModel().budget)):
            new = incremental_dse(layers, hw, budget)
            ref = incremental_dse_ref(layers, hw, budget)
            assert new.designs == ref.designs and new.trace == ref.trace \
                and new.throughput == ref.throughput \
                and new.resource == ref.resource, (name, hw_name)
            t_new = _best_of(lambda: incremental_dse(layers, hw, budget), reps)
            t_ref = _best_of(lambda: incremental_dse_ref(layers, hw, budget),
                             ref_reps)
            row = {"model": name, "hw": hw_name, "layers": len(layers),
                   "increments": len(new.trace),
                   "ref_ms": round(t_ref * 1e3, 2),
                   "new_ms": round(t_new * 1e3, 2),
                   "speedup": round(t_ref / t_new, 1),
                   "dse_per_s": round(1.0 / t_new, 1)}
            rows.append(row)
            print(f"  {name:13s} {hw_name:4s} L={row['layers']:3d} "
                  f"ref={row['ref_ms']:8.1f}ms new={row['new_ms']:6.1f}ms "
                  f"{row['speedup']:6.1f}x  ({row['dse_per_s']:.0f} DSE/s)")
    return rows


def bench_search_engine(iters: int = 64, dim: int = 16):
    """Search-loop overhead with a free evaluator: trials/sec of the serial
    ask/tell loop vs the batched frontier (TPE modeling cost amortizes over
    each batch)."""

    def synth(x):
        return {"acc": float(np.cos(3 * x).mean()), "spa": float(np.mean(x)),
                "thr": 1.0 + float(np.sum(x)),
                "thr_norm": float(np.tanh(np.mean(x))),
                "dsp": float(np.mean(x) ** 2)}

    out = {}
    for label, kw in (("serial", {}), ("batch8", {"batch_size": 8}),
                      ("batch16", {"batch_size": 16})):
        t0 = time.perf_counter()
        r = hass_search(synth, dim // 2, iters=iters, seed=0, **kw)
        dt = time.perf_counter() - t0
        assert len(r.trials) == iters
        out[label] = round(iters / dt, 1)
        print(f"  search engine {label:8s} {out[label]:10.1f} trials/s")
    return out


def bench_partition(n_parts: int = 3, batch: int = 256,
                    reconfig: float = 1e6, dse_iters: int = 120):
    """Segment-table DP vs the retained SA baseline: identical objective,
    and the DP optimum is exact (``thr_gain`` >= 1 by construction). The DP
    pays at most one DSE per contiguous segment (L(L+1)/2, independent of
    schedule length) where SA pays steps x partitions DSEs yet only samples
    the cut space — so DP wall-clock can exceed SA's 60-step default on deep
    nets while never scoring worse. Plus the partitioned multi-chip TPU mode
    (ICI-aware switches)."""
    rows = []
    for name, cfg in (("resnet18", RESNET18), ("mobilenetv3s", MOBILENETV3S)):
        layers = _sparse_workload(cfg)
        hw, budget = FPGAModel(), 4096.0
        kw = dict(n_parts=n_parts, batch=batch, reconfig_cycles=reconfig,
                  dse_iters=dse_iters)
        # both are deterministic at fixed seed: time the run that is kept
        dp, us_dp = timed(lambda: partition_pipeline(layers, hw, budget, **kw))
        sa, us_sa = timed(lambda: partition_pipeline_sa(layers, hw, budget,
                                                        seed=0, **kw))
        assert dp.throughput >= sa.throughput * (1 - 1e-12), (name, "DP<SA")
        row = {"model": name, "hw": "fpga", "layers": len(layers),
               "dp_ms": round(us_dp / 1e3, 2), "sa_ms": round(us_sa / 1e3, 2),
               "dp_thr": dp.throughput, "sa_thr": sa.throughput,
               "thr_gain": round(dp.throughput / max(sa.throughput, 1e-30), 3),
               "dse_calls": dp.dse_calls, "cuts": dp.cuts}
        rows.append(row)
        print(f"  partition {name:13s} DP={row['dp_ms']:8.1f}ms "
              f"SA={row['sa_ms']:8.1f}ms thr_gain={row['thr_gain']:.3f}x "
              f"dse_calls={dp.dse_calls} cuts={dp.cuts}")
    # multi-chip TPU: per-chip partitions, ICI-aware switch term
    layers = _sparse_workload(RESNET18)
    tpu = TPUModel(chips=4)
    mp = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                            batch=batch, dse_iters=dse_iters)
    rows.append({"model": "resnet18", "hw": "tpu_x4", "layers": len(layers),
                 "dp_thr": mp.throughput, "steady_thr": mp.steady_throughput,
                 "dse_calls": mp.dse_calls, "cuts": mp.cuts})
    print(f"  partition resnet18 tpu_x4 cuts={mp.cuts} "
          f"amortized={mp.throughput * tpu.freq:.0f} "
          f"steady={mp.steady_throughput * tpu.freq:.0f} img/s")
    return rows


def run(reps: int = 5):
    print("incremental_dse: scalar reference vs vectorized")
    rows = bench_dse(reps=reps)
    print("partition_pipeline: segment-table DP vs SA baseline")
    part_rows = bench_partition()
    print("hass_search engine throughput (synthetic evaluator)")
    engine = bench_search_engine()
    worst = min(r["speedup"] for r in rows)
    mean = float(np.mean([r["speedup"] for r in rows]))
    save_json("dse_bench.json", {"rows": rows, "partition": part_rows,
                                 "engine_trials_per_s": engine,
                                 "worst_speedup": worst,
                                 "mean_speedup": round(mean, 1)})
    total_new = sum(r["new_ms"] for r in rows)
    emit("dse_bench.incremental_dse", total_new * 1e3,
         f"worst={worst:.1f}x mean={mean:.1f}x over "
         f"{len(rows)} paper-CNN workloads")
    assert worst >= 10.0, f"vectorized DSE speedup regressed: {worst:.1f}x"
    return rows


if __name__ == "__main__":
    run()
