"""Observability gates: tracing must be invisible, cheap, and well-formed.

The obs subsystem (``repro.obs``: process-global tracer + search flight
recorder, DESIGN.md §18) instruments the hottest loops in the repo —
``hass_search``, the DSE cache, the sim engine, the fleet — so it gets
the same treatment the acceleration subsystem got in
``search_bench.py``: hard gates, not vibes. Three of them, saved to
``experiments/obs_bench.json``:

  * ``identity`` — the same fixed-seed ``hass_search`` runs three times:
    reference (tracer never touched), tracer explicitly disabled, and
    tracer enabled with a flight recorder attached. All three transcripts
    must be bit-identical, trial for trial (x, score, metrics,
    best_score). Instrumentation only reads clocks and counters; it must
    never move a float.
  * ``overhead`` — tracer-on wall clock within ``OVERHEAD_GATE`` of
    tracer-off. The gated statistic is the min over repetitions of the
    PAIRED per-rep ratio (both arms back to back, order alternating, GC
    off, ~1 s timed intervals): ambient load cancels inside each pair,
    and the min picks the quietest window, so the gate only trips on a
    real regression.
  * ``trace`` — the exported Chrome trace (committed as
    ``experiments/obs_trace.json``) validates against the trace-event
    schema: ``{"traceEvents": [...]}``, every event a complete ("X")
    event with string name and finite numeric ts/dur >= 0, and at least
    one ``trial`` span per search trial.

Plus the flight-recorder contract (footer totals == sum of per-trial
records; every line re-parses) and the ``tools/trace_report.py``
acceptance check: a diff of two same-seed recorded runs reports ZERO
trial divergence, a diff across seeds reports per-phase deltas.

    PYTHONPATH=src:. python benchmarks/obs_bench.py [--smoke]
"""
import argparse
import gc
import io
import json
import math
import os
import tempfile
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro.configs import get_config
from repro.core.hass import LMEvaluator, hass_search
from repro.core.perf_model import TPUModel
from repro.obs import FlightRecorder, Tracer, load_run, set_tracer
from tools.trace_report import diff_runs
from tools.trace_report import load_run as report_load_run

OVERHEAD_GATE = 0.03   # tracer-on may cost at most 3% wall clock


def _assert_identical(a, b, tag):
    """Trial-for-trial bit-exactness between two search transcripts."""
    assert len(a.trials) == len(b.trials), tag
    for ta, tb in zip(a.trials, b.trials):
        assert np.array_equal(ta.x, tb.x), (tag, "proposal diverged")
        assert ta.score == tb.score, (tag, "score diverged")
        assert ta.metrics == tb.metrics, (tag, "metrics diverged")
    assert a.best_score == b.best_score, tag


def _make_ev(dse_iters: int):
    cfg = get_config("qwen3-0.6b")
    tpu = TPUModel()
    return LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=dse_iters)


def _search(ev, **kw):
    t0 = time.perf_counter()
    r = hass_search(ev, ev.n_search, **kw)
    return r, time.perf_counter() - t0


def bench_identity(iters: int, dse_iters: int, seed: int = 0):
    """Gate (a): reference == tracer-off == tracer-on+recorder, and the
    recorder's own footer-equals-sum-of-trials invariant."""
    kw = dict(iters=iters, seed=seed, include_act=False)
    r_ref, _ = _search(_make_ev(dse_iters), **kw)
    set_tracer(None)                       # explicit off (the default)
    r_off, _ = _search(_make_ev(dse_iters), **kw)
    rec_path = os.path.join(tempfile.gettempdir(), "obs_bench_run.jsonl")
    tr = Tracer()
    set_tracer(tr)
    try:
        with FlightRecorder(rec_path) as rec:
            r_on, _ = _search(_make_ev(dse_iters), recorder=rec, **kw)
    finally:
        set_tracer(None)
    _assert_identical(r_ref, r_off, "tracer-off")
    _assert_identical(r_ref, r_on, "tracer-on")

    run = load_run(rec_path)
    assert run["footer"] is not None, "recorder wrote no footer"
    assert run["footer"]["n_trials"] == len(run["trials"]) == iters
    for field in ("cache", "engine", "phases"):
        tot = {}
        for t in run["trials"]:
            for k, v in (t.get(field) or {}).items():
                tot[k] = tot.get(k, 0) + v
        foot = run["footer"]["totals"][field]
        for k in set(tot) | set(foot):
            got, want = foot.get(k, 0), tot.get(k, 0)
            ok = got == want or math.isclose(got, want, rel_tol=1e-9)
            assert ok, (field, k, got, want)
    print(f"  identity: {iters} trials x 3 arms bit-identical; recorder "
          f"footer == sum of {len(run['trials'])} trial records")
    return {"iters": iters, "arms": ["reference", "tracer-off", "tracer-on"],
            "identical": True, "records": len(run["trials"]) + 2,
            "best_score": r_ref.best_score}, tr, rec_path


def bench_overhead(iters: int, dse_iters: int, reps: int, seed: int = 0):
    """Gate (b): tracer-on wall clock within OVERHEAD_GATE of tracer-off,
    interleaved min-of-reps. The true cost is a handful of clock reads
    per trial — far below the gate — so the enemy here is scheduler
    noise, not the tracer: one untimed warmup absorbs lazy imports and
    allocator growth, GC stays off during timing (one collection pause
    exceeds the gate on its own), arm order alternates per repetition so
    drift cancels, each timed interval runs enough trials (~1 s) that
    preemption noise amortizes below the gate, and the min over
    repetitions is the load-robust estimator."""
    kw = dict(iters=iters, seed=seed, include_act=False)
    _search(_make_ev(dse_iters), iters=48, seed=seed,
            include_act=False)             # untimed warmup

    def run_off():
        return _search(_make_ev(dse_iters), **kw)

    def run_on():
        set_tracer(Tracer())
        try:
            return _search(_make_ev(dse_iters), **kw)
        finally:
            set_tracer(None)

    ratios = []
    t_off = t_on = float("inf")
    gc.collect()
    gc.disable()                     # a GC pause is >3% of one repetition
    try:
        for rep in range(reps):
            # alternate arm order so clock drift / thermal ramp cancels
            first, second = (run_off, run_on) if rep % 2 == 0 \
                else (run_on, run_off)
            (ra, dta), (rb, dtb) = first(), second()
            dt_off, dt_on = (dta, dtb) if rep % 2 == 0 else (dtb, dta)
            t_off = min(t_off, dt_off)
            t_on = min(t_on, dt_on)
            # the gated statistic is PAIRED per repetition: the two arms
            # of one rep run back to back, so sustained ambient load
            # cancels inside each ratio; the min over reps then picks the
            # quietest window. A real multi-percent regression shifts
            # every ratio and still trips the gate.
            ratios.append(dt_on / dt_off)
            _assert_identical(ra, rb, "overhead")
    finally:
        gc.enable()
    overhead = min(ratios) - 1.0
    print(f"  overhead: off={t_off * 1e3:.1f}ms on={t_on * 1e3:.1f}ms  "
          f"paired min {overhead * 100:+.2f}%  "
          f"(gate {OVERHEAD_GATE * 100:.0f}%)")
    assert overhead < OVERHEAD_GATE, \
        f"tracer overhead {overhead * 100:.2f}% >= {OVERHEAD_GATE * 100:.0f}%"
    return {"iters": iters, "reps": reps,
            "off_ms": round(t_off * 1e3, 2), "on_ms": round(t_on * 1e3, 2),
            "paired_ratios": [round(r, 4) for r in ratios],
            "overhead_pct": round(overhead * 100, 2),
            "gate_pct": OVERHEAD_GATE * 100}


def bench_trace(tr: Tracer, iters: int):
    """Gate (c): the exported Chrome trace is schema-valid and carries
    >=1 ``trial`` span per search trial."""
    path = tr.export_chrome_trace(os.path.join(RESULTS_DIR,
                                               "obs_trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and isinstance(doc.get("traceEvents"),
                                                list), "not a trace doc"
    trials = 0
    for ev in doc["traceEvents"]:
        assert ev.get("ph") == "X", ev
        assert isinstance(ev.get("name"), str) and ev["name"], ev
        for k in ("ts", "dur"):
            v = ev.get(k)
            assert isinstance(v, (int, float)) and math.isfinite(v) \
                and v >= 0, (k, ev)
        assert isinstance(ev.get("pid"), int), ev
        assert isinstance(ev.get("tid"), int), ev
        trials += ev["name"] == "trial"
    assert trials >= iters, \
        f"{trials} trial spans < {iters} search trials"
    rel = os.path.relpath(path, os.path.join(RESULTS_DIR, ".."))
    print(f"  trace: {len(doc['traceEvents'])} events schema-valid, "
          f"{trials} trial spans (>= {iters} trials) -> {rel}")
    return {"events": len(doc["traceEvents"]), "trial_spans": trials,
            "path": rel}


def bench_report(iters: int, dse_iters: int):
    """Acceptance check on ``tools/trace_report.py``: same-seed diff is
    zero-divergence, cross-seed diff reports per-phase deltas."""
    paths = {}
    for tag, seed in (("a", 0), ("b", 0), ("c", 1)):
        p = os.path.join(tempfile.gettempdir(), f"obs_bench_{tag}.jsonl")
        with FlightRecorder(p) as rec:
            hass_search(_make_ev(dse_iters), _make_ev(dse_iters).n_search,
                        iters=iters, seed=seed, include_act=False,
                        recorder=rec)
        paths[tag] = p
    same = io.StringIO()
    n_same = diff_runs(report_load_run(paths["a"]),
                       report_load_run(paths["b"]), out=same)
    cross = io.StringIO()
    n_cross = diff_runs(report_load_run(paths["a"]),
                        report_load_run(paths["c"]), out=cross)
    assert n_same == 0, f"same-seed diff found {n_same} diverging trials"
    assert n_cross > 0, "cross-seed diff found no divergence"
    assert "phase deltas" in cross.getvalue(), "diff omitted phase deltas"
    print(f"  report: same-seed diff 0 diverging trials, cross-seed "
          f"{n_cross}/{iters} diverge + phase deltas")
    for p in paths.values():
        os.remove(p)
    return {"same_seed_divergence": n_same,
            "cross_seed_divergence": n_cross}


def run(smoke: bool = False):
    iters = 24 if smoke else 48
    dse_iters = 300
    reps = 3 if smoke else 5

    print("obs gates: identity / overhead / trace schema / report diff")
    id_row, tr, rec_path = bench_identity(iters, dse_iters)
    ov_row = bench_overhead(400, dse_iters, reps)
    trace_row = bench_trace(tr, iters)
    rep_row = bench_report(iters, dse_iters)
    os.remove(rec_path)

    payload = {"smoke": smoke, "overhead_gate_pct": OVERHEAD_GATE * 100,
               "identity": id_row, "overhead": ov_row, "trace": trace_row,
               "report": rep_row}
    save_json("obs_bench.json", payload)
    emit("obs_bench.tracer_on", ov_row["on_ms"] * 1e3,
         f"overhead={ov_row['overhead_pct']:+.2f}% "
         f"(gate {OVERHEAD_GATE * 100:.0f}%), 3-arm transcripts "
         f"bit-identical, {trace_row['trial_spans']} trial spans, "
         f"same-seed diff divergence=0")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trial count / repetitions for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
