"""End-to-end ``hass_search`` speed gate (DESIGN.md §12).

The search-loop acceleration subsystem (DSECache + class-grouped DSE engine
+ presorted tau tables + vectorized stack realization) must be FAST and
INVISIBLE: every section runs the same fixed-seed search twice — the
``baseline`` arm is the seed code path (``accel=False``, flat DSE engine,
no cache) and the ``accel`` arm is the subsystem — and asserts the two
produce bit-identical trial sequences (same x, same score, same metrics,
trial for trial) before gating the wall-clock ratio.

Sections, saved to ``experiments/search_bench.json``:

  * ``cnn``   — ResNet-18 ``CNNEvaluator`` search (the paper's Fig. 5
    structure). The seed path re-sorts every weight tensor inside each
    jitted evaluation (jnp.quantile); the accel arm gathers from presorted
    tables. Gate: >=5x, identical trials.
  * ``lm``    — ``LMEvaluator`` searches on LM stacks (sample = token).
    The accel arm swaps s_eff into one LayerVectors template and runs the
    class-grouped greedy through the DSECache. Gate: >=5x, identical
    trials per model.
  * ``sweep`` — deployment sweep: partition the best sparse stacks across
    1/4/8 chips (both DP objectives) with ONE shared DSECache vs the seed
    behavior of a fresh segment table per call. Gate: >=SWEEP_GATEx fewer
    cold DSE runs, identical PartitionResults.
  * ``sensitivity`` — per-kind probes around the best proposal; deltas
    confined to floor-stable kinds certify the DSECache warm-start
    theorem. Reported, plus a weak >=1 warm-hit gate.
  * ``liar``  — constant-liar vs independent-draw batch proposals at equal
    trial budget (report-only: search quality, not speed).
  * ``batch`` — proposal-batched DSE (DESIGN.md §15): both arms run the
    full acceleration subsystem, but the serial arm pins
    ``batch_dse=False`` so every proposal of a TPE wave pays its own
    engine dispatch, while the batch arm advances the whole wave in ONE
    ``incremental_dse_batch`` invocation. Gate: bit-identical trial
    sequences always; >=BATCH_GATEx wall-clock when the compiled C
    backend is available (the numpy lockstep fallback is correctness-only
    and exempt from the speed gate).

    PYTHONPATH=src:. python benchmarks/search_bench.py [--smoke]
"""
import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, save_json, trained_cnn
from repro.configs import get_config
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import DSECache, partition_pipeline
from repro.core.hass import CNNEvaluator, LMEvaluator, hass_search
from repro.core.perf_model import (FPGAModel, TPUModel, lm_block_bounds,
                                   thin_cut_points)

SPEED_GATE = 5.0          # end-to-end accel-vs-seed search speedup
SWEEP_GATE_FULL = 10.0    # cold-DSE-run reduction in the deployment sweep
SWEEP_GATE_SMOKE = 4.0    # smoke runs fewer chip counts -> less reuse
BATCH_GATE_FULL = 3.0     # batched-wave vs per-proposal engine dispatch
BATCH_GATE_SMOKE = 2.0    # smoke runs fewer waves -> less amortization


def _assert_identical(a, b, tag):
    """Trial-for-trial bit-exactness between the two arms."""
    assert len(a.trials) == len(b.trials), tag
    for ta, tb in zip(a.trials, b.trials):
        assert np.array_equal(ta.x, tb.x), (tag, "proposal diverged")
        assert ta.score == tb.score, (tag, "score diverged")
        assert ta.metrics == tb.metrics, (tag, "metrics diverged")
    assert a.best_score == b.best_score, tag


def _timed_search(ev, n_search, **kw):
    t0 = time.perf_counter()
    r = hass_search(ev, n_search, **kw)
    return r, time.perf_counter() - t0


def _cache_str(stats) -> str:
    """The DSECache reuse counters as a printable suffix."""
    return (f"cache hits={stats['hits']} warm_l1={stats['warm_l1']} "
            f"warm_l2={stats['warm_l2']} cold={stats['cold_runs']}")


def bench_cnn(iters: int, seed: int = 0, img_res: int = 32):
    cfg = dataclasses.replace(RESNET18, img_res=img_res)
    params = trained_cnn(cfg, steps=10)
    import jax
    images = jax.random.normal(jax.random.PRNGKey(seed),
                               (8, img_res, img_res, 3))

    def make(accel):
        return CNNEvaluator(cfg, params, images, FPGAModel(), budget=4096,
                            dse_iters=400, cost_cfg=RESNET18, accel=accel,
                            dse_engine="auto" if accel else "flat")

    ev_b, ev_a = make(False), make(True)
    kw = dict(iters=iters, seed=seed, s_max=0.9)
    r_b, t_b = _timed_search(ev_b, len(ev_b.prunable), **kw)
    r_a, t_a = _timed_search(ev_a, len(ev_a.prunable), **kw)
    _assert_identical(r_b, r_a, "cnn")
    speedup = t_b / t_a
    row = {"model": "resnet18", "iters": iters,
           "baseline_s": round(t_b, 2), "accel_s": round(t_a, 2),
           "speedup": round(speedup, 1),
           "best_score": r_a.best_score,
           "cache": ev_a.dse_cache.stats()}
    print(f"  cnn resnet18      {iters:3d} trials  "
          f"seed-path={t_b:7.1f}s  accel={t_a:6.1f}s  {speedup:6.1f}x  "
          f"(identical trials, {_cache_str(row['cache'])})")
    assert speedup >= SPEED_GATE, \
        f"CNN search speedup regressed: {speedup:.1f}x < {SPEED_GATE}x"
    return row, ev_a, r_a


def bench_lm(models, iters: int, seed: int = 0, dse_iters: int = 300):
    rows = []
    best = {}
    for name in models:
        cfg = get_config(name)
        tpu = TPUModel()

        def make(accel):
            return LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=dse_iters,
                               accel=accel,
                               dse_engine="auto" if accel else "flat")

        # both arms run the batched frontier (the examples' default): one
        # TPE model fit serves a whole round, so the proposal engine's cost
        # — identical in both arms — does not dilute the evaluation-path
        # ratio the gate is about. liar=None keeps rounds single-fit.
        kw = dict(iters=iters, seed=seed, include_act=False,
                  batch_size=8, liar=None)
        # min of 3 fresh-evaluator repetitions per arm: LM searches are
        # sub-second, so one scheduler hiccup would dominate the ratio
        t_b = t_a = float("inf")
        for _ in range(3):
            ev_b, ev_a = make(False), make(True)
            r_b, dt = _timed_search(ev_b, ev_b.n_search, **kw)
            t_b = min(t_b, dt)
            r_a, dt = _timed_search(ev_a, ev_a.n_search, **kw)
            t_a = min(t_a, dt)
            _assert_identical(r_b, r_a, name)
        speedup = t_b / t_a
        rows.append({"model": name, "iters": iters,
                     "baseline_s": round(t_b, 2), "accel_s": round(t_a, 2),
                     "speedup": round(speedup, 1),
                     "trials_per_s": round(iters / t_a, 1),
                     "best_score": r_a.best_score,
                     "cache": ev_a.dse_cache.stats()})
        best[name] = (ev_a, r_a)
        print(f"  lm  {name:14s}{iters:3d} trials  "
              f"seed-path={t_b:7.1f}s  accel={t_a:6.1f}s  {speedup:6.1f}x  "
              f"(identical trials, {iters / t_a:.0f} trials/s, "
              f"{_cache_str(rows[-1]['cache'])})")
        assert speedup >= SPEED_GATE, \
            f"{name} search speedup regressed: {speedup:.1f}x < {SPEED_GATE}x"
    return rows, best


def bench_batch(iters: int, gate: float, seed: int = 0, batch_size: int = 8,
                dse_iters: int = 300, reps: int = 5):
    """Proposal-batched DSE vs per-proposal dispatch, same fixed-seed
    search. Unlike the cnn/lm sections (subsystem vs seed path), BOTH arms
    here run the full acceleration subsystem — cache, warm starts, grouped
    C engine — and differ only in ``batch_dse``: the serial arm walks a
    TPE wave proposal by proposal (one ``dse_vec`` per member), the batch
    arm hands the whole wave to ``DSECache.dse_vec_batch`` which runs all
    cold members in one ``incremental_dse_batch`` engine invocation.
    Bit-identical trial sequences are asserted on every repetition; the
    wall-clock gate applies only with the compiled backend (the numpy
    lockstep fallback interprets the batch loop and is correctness-only).
    """
    from repro.core import _dse_ckernel
    cfg = get_config("qwen3-0.6b")
    tpu = TPUModel()
    kw = dict(iters=iters, seed=seed, include_act=False,
              batch_size=batch_size, liar=None)

    def make(batch_dse):
        return LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=dse_iters,
                           batch_dse=batch_dse)

    # min over fresh-evaluator repetitions per arm: both arms are tens of
    # milliseconds, so the min is the only load-robust estimator here
    t_s = t_a = float("inf")
    for _ in range(reps):
        ev_s, ev_a = make(False), make(True)
        r_s, dt = _timed_search(ev_s, ev_s.n_search, **kw)
        t_s = min(t_s, dt)
        r_a, dt = _timed_search(ev_a, ev_a.n_search, **kw)
        t_a = min(t_a, dt)
        _assert_identical(r_s, r_a, "batch")
    compiled = _dse_ckernel.get_lib() is not None
    speedup = t_s / t_a
    row = {"model": "qwen3-0.6b", "iters": iters, "batch_size": batch_size,
           "engine": "compiled" if compiled else "lockstep",
           "serial_ms": round(t_s * 1e3, 1), "batched_ms": round(t_a * 1e3, 1),
           "speedup": round(speedup, 2), "gate": gate,
           "best_score": r_a.best_score,
           "cache": ev_a.dse_cache.stats()}
    print(f"  batch qwen3-0.6b  {iters:3d} trials/wave={batch_size}  "
          f"per-proposal={t_s * 1e3:6.1f}ms  batched={t_a * 1e3:6.1f}ms  "
          f"{speedup:5.2f}x  (identical trials, {row['engine']} engine, "
          f"{_cache_str(row['cache'])})")
    if compiled:
        assert speedup >= gate, \
            f"batched-DSE speedup regressed: {speedup:.2f}x < {gate}x"
    else:
        print("  batch: compiled backend unavailable -> lockstep fallback, "
              "speed gate skipped (identity still asserted)")
    return row


def bench_sweep(stacks, chips_list, batches, dse_iters: int):
    """Deployment sweep: 1/4/8-chip partitions x both DP objectives x
    pipeline batch sizes of the same sparse stacks — the standard
    latency/throughput/slice-size study. The seed behavior pays a fresh
    segment table per ``partition_pipeline`` call (segment frontiers are
    batch-independent, but the table dies with the call); one shared
    ``DSECache`` pays each distinct (segment, sparsity) DSE once across
    the WHOLE sweep."""
    rows = []
    for tag, layers, cut_points in stacks:
        plans = []
        for batch in batches:
            for chips in chips_list:
                for objective in (("sum",) if chips == 1
                                  else ("sum", "maxmin")):
                    plans.append((chips, objective, batch))

        def sweep(cache):
            out = []
            calls = 0
            for chips, objective, batch in plans:
                tpu = TPUModel(chips=chips)
                p = partition_pipeline(
                    layers, tpu, tpu.chip_budget, n_parts=chips, batch=batch,
                    dse_iters=dse_iters, cut_points=cut_points,
                    objective=objective, cache=cache)
                calls += p.dse_calls
                out.append(p)
            return out, calls

        t0 = time.perf_counter()
        base, base_calls = sweep(None)
        t_b = time.perf_counter() - t0
        cache = DSECache()
        t0 = time.perf_counter()
        acc, _ = sweep(cache)
        t_a = time.perf_counter() - t0
        for p, q in zip(base, acc):
            assert p.cuts == q.cuts and p.objective == q.objective, tag
            assert p.time_per_batch == q.time_per_batch, tag
            assert p.throughput == q.throughput, tag
            assert p.steady_throughput == q.steady_throughput, tag
        cold = cache.stats()["cold_runs"]
        reduction = base_calls / max(cold, 1)
        rows.append({"stack": tag, "plans": len(plans),
                     "segment_dses_uncached": base_calls,
                     "cold_runs_cached": cold,
                     "cold_reduction": round(reduction, 1),
                     "baseline_s": round(t_b, 2), "accel_s": round(t_a, 2),
                     "speedup": round(t_b / max(t_a, 1e-9), 1),
                     "cache": cache.stats()})
        print(f"  sweep {tag:16s}{len(plans):2d} partition calls: "
              f"{base_calls:4d} segment DSEs -> {cold:4d} cold "
              f"({reduction:.1f}x fewer), wall {t_b:.1f}s -> {t_a:.1f}s")
    return rows


def bench_sensitivity(ev, best_x, delta: float = 0.05):
    """Per-kind probes around the incumbent: deltas confined to one search
    variable leave every other layer untouched, so probes on kinds whose
    layers stay at the DSE resource floor certify the warm-start theorem
    (cache returns the incumbent's result, bit-exact)."""
    cache = ev.dse_cache
    before = dict(cache.stats())
    ev(best_x)
    for k in range(ev.n_search):
        for d in (-delta, delta):
            x = np.array(best_x, dtype=float).copy()
            x[k] = float(np.clip(x[k] + d, 0.0, 0.95))
            ev(x)
    after = cache.stats()
    probes = 2 * ev.n_search
    row = {"probes": probes,
           "exact_hits": after["hits"] - before["hits"],
           "warm_hits": after["warm_hits"] - before["warm_hits"],
           "cold_runs": after["cold_runs"] - before["cold_runs"]}
    print(f"  sensitivity: {probes} probes -> {row['warm_hits']} warm + "
          f"{row['exact_hits']} exact hits, {row['cold_runs']} cold")
    return row


def bench_liar(models, iters: int, batch_size: int = 6, seed: int = 0,
               dse_iters: int = 300):
    """Constant-liar vs independent-draw batches at equal trial budget."""
    rows = []
    for name in models:
        cfg = get_config(name)
        tpu = TPUModel()
        scores = {}
        for liar in ("min", None):
            ev = LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=dse_iters)
            r = hass_search(ev, ev.n_search, iters=iters, seed=seed,
                            include_act=False, batch_size=batch_size,
                            liar=liar)
            scores["liar" if liar else "independent"] = r.best_score
        rows.append({"model": name, "iters": iters,
                     "batch_size": batch_size, **scores})
        print(f"  liar {name:14s} best: constant-liar={scores['liar']:.4f} "
              f"independent={scores['independent']:.4f}")
    return rows


def run(smoke: bool = False):
    lm_models = ["qwen3-0.6b"] if smoke else ["qwen3-0.6b", "mixtral-8x7b"]
    cnn_iters = 8 if smoke else 16
    lm_iters = 24 if smoke else 48
    # the sweep models a real deployment study: how do the best stacks
    # partition across every slice size we could rent — more chip counts,
    # more reuse of the same segment frontiers
    chips_list = (1, 2, 4) if smoke else (1, 2, 3, 4, 6, 8)
    dse_iters = 300
    sweep_gate = SWEEP_GATE_SMOKE if smoke else SWEEP_GATE_FULL

    print("hass_search end-to-end: seed path vs acceleration subsystem")
    cnn_row, cnn_ev, cnn_res = bench_cnn(cnn_iters)
    lm_rows, lm_best = bench_lm(lm_models, lm_iters, dse_iters=dse_iters)
    batch_row = bench_batch(lm_iters,
                            gate=BATCH_GATE_SMOKE if smoke else BATCH_GATE_FULL,
                            dse_iters=dse_iters, reps=3 if smoke else 5)

    stacks = [("resnet18", cnn_ev.sparse_layers(cnn_res.best_x), None)]
    for name, (ev, r) in lm_best.items():
        layers = ev.sparse_layers(r.best_x)
        cuts = thin_cut_points(lm_block_bounds(layers), 8 if smoke else 12)
        stacks.append((name, layers, cuts))
    batches = (32, 128) if smoke else (32, 128, 512)
    print(f"deployment sweep ({list(chips_list)} chips x objectives x "
          f"{list(batches)} batch, shared DSECache vs per-call tables)")
    sweep_rows = bench_sweep(stacks, chips_list, batches,
                             dse_iters=dse_iters)
    worst_red = min(r["cold_reduction"] for r in sweep_rows)
    assert worst_red >= sweep_gate, \
        f"sweep cold-DSE reduction regressed: {worst_red:.1f}x < {sweep_gate}x"

    name0 = lm_models[0]
    sens_row = bench_sensitivity(*[lm_best[name0][0], lm_best[name0][1].best_x])
    assert sens_row["warm_hits"] + sens_row["exact_hits"] >= 1, \
        "warm-start certificate never fired on sensitivity probes"

    liar_rows = bench_liar(lm_models[:1], iters=24 if smoke else 48,
                           dse_iters=dse_iters)

    worst = min([cnn_row["speedup"]] + [r["speedup"] for r in lm_rows])
    payload = {"smoke": smoke, "speed_gate": SPEED_GATE,
               "sweep_gate": sweep_gate, "cnn": cnn_row, "lm": lm_rows,
               "batch": batch_row, "sweep": sweep_rows,
               "sensitivity": sens_row, "liar": liar_rows,
               "worst_search_speedup": worst,
               "worst_sweep_reduction": worst_red}
    save_json("search_bench.json", payload)
    emit("search_bench.hass_search",
         (cnn_row["accel_s"] + sum(r["accel_s"] for r in lm_rows)) * 1e6,
         f"worst_speedup={worst:.1f}x (gate {SPEED_GATE}x) "
         f"sweep_cold_reduction={worst_red:.1f}x (gate {sweep_gate}x) "
         f"batched_dse={batch_row['speedup']:.2f}x "
         f"(gate {batch_row['gate']}x), "
         f"iso-results asserted trial-for-trial")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced set for CI (one LM model, 1/4-chip sweep)")
    args = ap.parse_args()
    run(smoke=args.smoke)
